"""CI benchmark regression gate: compare a fresh bench artifact against the
committed baseline and fail the job on large slowdowns.

Compares every metric row whose name ends in ``--suffix`` (default
``/chunks_per_sec``, the engine-throughput headline) between the measured
artifact and the committed baseline. Single runs on shared CI runners are
noisy — a 2x spread run-to-run is normal — so the gate is deliberately
generous: it FAILS only below ``--fail-below`` (default 0.5x baseline, which
a real regression like an accidentally reintroduced ``jnp.unique`` or an
un-fused reclaim pass clears by a wide margin) and WARNS between
``--warn-below`` and the fail floor. The comparison table is appended to
``$GITHUB_STEP_SUMMARY`` when set (or ``--summary PATH``).

  PYTHONPATH=src python -m benchmarks.check_regression \\
      --measured bench-artifacts/BENCH_engine.json \\
      --baseline benchmarks/BENCH_engine.json --baseline-key tiny_baseline

``--baseline-key`` selects a sub-document of the baseline JSON: the
committed ``BENCH_engine.json`` carries the full-geometry rows at top level
and the CI-geometry (``--tiny``) rows under ``"tiny_baseline"``, so the
smoke run compares like with like.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

FAIL_BELOW = 0.5
WARN_BELOW = 0.8
SUFFIX = "/chunks_per_sec"


def rows_to_metrics(doc: dict, suffix: str) -> dict[str, float]:
    return {name: float(value) for name, value, _unit in doc.get("rows", [])
            if name.endswith(suffix)}


def gate(measured_doc: dict, baseline_doc: dict, fail_below: float = FAIL_BELOW,
         warn_below: float = WARN_BELOW, suffix: str = SUFFIX,
         require: tuple[str, ...] = ()):
    """Compare matching metric rows. Returns a list of
    ``(name, measured, baseline, ratio, status)`` with status in
    OK/WARN/FAIL. Raises if the docs share no comparable rows — a gate that
    compares nothing must not pass silently — or if a ``require``'d metric
    (a named member of the guarded set, e.g. the gc_pressure section) is
    absent from either side."""
    measured = rows_to_metrics(measured_doc, suffix)
    baseline = rows_to_metrics(baseline_doc, suffix)
    for name in require:
        if not name.endswith(suffix):
            raise ValueError(
                f"required metric {name!r} does not end with the compared "
                f"suffix {suffix!r}; the gate would never see it"
            )
        if name not in measured or name not in baseline:
            raise ValueError(
                f"required metric {name!r} missing from "
                f"{'measured' if name not in measured else 'baseline'} artifact"
            )
    common = sorted(set(measured) & set(baseline))
    if not common:
        raise ValueError(
            f"no common rows ending in {suffix!r}: measured has "
            f"{sorted(measured)}, baseline has {sorted(baseline)}"
        )
    # a baseline row with no measured counterpart means a guarded section
    # silently vanished from the bench — that must not pass as green
    missing = sorted(set(baseline) - set(measured))
    if missing:
        raise ValueError(
            f"baseline rows missing from the measured artifact: {missing} "
            "(did a bench section stop emitting?)"
        )
    out = []
    for name in common:
        ratio = measured[name] / baseline[name]
        status = ("FAIL" if ratio < fail_below
                  else "WARN" if ratio < warn_below else "OK")
        out.append((name, measured[name], baseline[name], ratio, status))
    return out


def render_markdown(entries, fail_below: float, warn_below: float) -> str:
    icon = {"OK": "✅", "WARN": "⚠️", "FAIL": "❌"}
    lines = [
        "### Benchmark regression gate",
        "",
        f"fail < {fail_below:g}x baseline · warn < {warn_below:g}x "
        "(single CI runs are noisy; only large slowdowns fail)",
        "",
        "| metric | measured | baseline | ratio | status |",
        "|---|---:|---:|---:|:---:|",
    ]
    for name, m, b, ratio, status in entries:
        lines.append(
            f"| `{name}` | {m:,.1f} | {b:,.1f} | {ratio:.2f}x "
            f"| {icon[status]} {status} |"
        )
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--measured", required=True, help="fresh bench artifact")
    ap.add_argument("--baseline", required=True, help="committed baseline JSON")
    ap.add_argument("--baseline-key", default=None,
                    help="use this sub-document of the baseline JSON "
                         "(e.g. tiny_baseline for the CI geometry)")
    ap.add_argument("--suffix", default=SUFFIX,
                    help="compare rows whose name ends with this")
    ap.add_argument("--fail-below", type=float, default=FAIL_BELOW)
    ap.add_argument("--warn-below", type=float, default=WARN_BELOW)
    ap.add_argument("--summary", default=None, metavar="PATH",
                    help="append the markdown table here "
                         "(default: $GITHUB_STEP_SUMMARY when set)")
    ap.add_argument("--require", action="append", default=[], metavar="NAME",
                    help="fail unless this metric row is present in both "
                         "artifacts (repeatable; pins the guarded set)")
    args = ap.parse_args(argv)

    measured_doc = json.loads(Path(args.measured).read_text())
    baseline_doc = json.loads(Path(args.baseline).read_text())
    if args.baseline_key:
        try:
            baseline_doc = baseline_doc[args.baseline_key]
        except KeyError:
            print(f"::error::baseline {args.baseline} has no key "
                  f"{args.baseline_key!r}")
            return 2

    entries = gate(measured_doc, baseline_doc, args.fail_below,
                   args.warn_below, args.suffix, require=tuple(args.require))

    for name, m, b, ratio, status in entries:
        print(f"{status:4s} {name}: {m:,.1f} vs baseline {b:,.1f} "
              f"({ratio:.2f}x)")
        if status == "WARN":
            print(f"::warning::{name} at {ratio:.2f}x baseline "
                  f"({m:,.1f} vs {b:,.1f})")
        elif status == "FAIL":
            print(f"::error::{name} regressed to {ratio:.2f}x baseline "
                  f"({m:,.1f} vs {b:,.1f}; fail floor {args.fail_below:g}x)")

    summary = args.summary or os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(render_markdown(entries, args.fail_below, args.warn_below))

    return 1 if any(e[4] == "FAIL" for e in entries) else 0


if __name__ == "__main__":
    sys.exit(main())
