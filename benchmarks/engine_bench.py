"""Engine-throughput benchmark (DESIGN.md §2A): chunks/sec for the simulator
hot path, measured for read-only, mixed read/write, GC-pressure,
fault-injection, and channel-contention traces.

The paper's headline figures (13-18) come from mixed traces, so this script
is the regression guard for the vectorized write path, the fused reclaim
pass, the fused multi-victim GC (the ``gc_pressure`` section runs a
write-heavy trace against a nearly-full device so GC fires on virtually
every chunk), the armed fault path (``mixed_faults``), the full wear-correlated
reliability model (``wearout``: wear-scaled draws, die-parity rebuild,
finite spare pool), and the lattice timing model's second Lindley pass
(``channel_contention``: open-loop zipf reads funneling 4 dies into 1
channel under ``chan_model="lattice"``): it
reports steady-state chunks/sec and wall-clock per chunk (compile excluded,
measured separately) and emits a ``BENCH_engine.json`` artifact in the same
``name,value,unit`` row format as the rest of the harness.

  PYTHONPATH=src python -m benchmarks.engine_bench [--tiny] [--repeats N]
      [--out DIR]

``--tiny`` runs the unit-test geometry (CI smoke); the default is a mid-size
geometry large enough that per-chunk work dominates dispatch overhead.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

# gc_pressure workload shape — single source of truth for the trace builder
# in _sections and the provenance dict emitted into BENCH_engine.json
GC_PRESSURE_READ_FRAC = 0.1
GC_PRESSURE_WRITE_THETA = 2.0

# fault rates for the mixed_faults section: high enough that every fault
# class fires (the section prices the injected draws + recovery scatters,
# not just the dormant branches), shared with the provenance dict
FAULT_MAX_READ_RETRIES = 6
FAULT_PROG_FAIL_RATE = 0.01
FAULT_ERASE_FAIL_RATE = 0.02
FAULT_SEED = 1

# wearout section knobs (DESIGN.md §2D, wear-correlated): mixed_faults plus
# the wear curve, probabilistic read faults, die-parity rebuild and a finite
# spare pool — prices the full reliability model (wear-multiplied draws,
# rebuild lattice charges, spare accounting, degraded-mode gating)
WEAROUT_READ_FAIL_RATE = 0.002
WEAROUT_WEAR_SLOPE = 8.0
WEAROUT_SPARE_BLOCKS = 12

# channel_contention workload shape (DESIGN.md §2C): read-heavy open-loop
# Zipf trace at an offered rate that keeps the one shared bus saturated, so
# the section prices the lattice model's second Lindley pass
CHAN_CONTENTION_RATE_IOPS = 30_000.0
CHAN_CONTENTION_READ_THETA = 1.2


def bench_config(tiny: bool):
    from repro.ssdsim import geometry

    if tiny:
        return geometry.tiny_config(policy=geometry.RARO, initial_pe=500)
    return geometry.SimConfig(
        blocks_per_plane=64,  # 256 blocks
        slots_per_block=256,
        n_logical=32_768,  # half the device, like the paper's 8 GiB / 16 GiB
        chunk=512,
        migrate_pages_per_chunk=64,
        max_conversions_per_chunk=4,
        gc_free_threshold=4,
        policy=geometry.RARO,
        initial_pe=500,
    )


def gc_pressure_config(tiny: bool):
    """Geometry for the ``gc_pressure`` section: the working set covers
    almost the whole device (a handful of free blocks) and the GC watermark
    sits above the free-pool guard, so the single-victim reference must fire
    on virtually every chunk just to keep up with the write rate (~1 block
    consumed per chunk), while the fused pass amortizes the same relocation
    work over one firing per ``gc_victims_per_pass`` chunks. BASELINE policy
    isolates GC: no conversion/reclaim churn competes for the free pool (a
    nearly-full device under RARO sits below the reclaim watermark by
    construction, which would drown the GC signal in demotion work)."""
    from repro.ssdsim import geometry

    if tiny:
        # 64 blocks: 46 used, 18 free == the watermark, so the 4-chunk CI
        # smoke reaches GC pressure immediately (guard floor for k=4 is 8+2)
        return geometry.tiny_config(
            policy=geometry.BASELINE, initial_pe=500,
            n_logical=2_944, gc_free_threshold=18, gc_victims_per_pass=4,
        )
    # 256 blocks: 224 used, 32 free; up to k=8 victims per firing (floor
    # 12+2). chunk=256 keeps the per-chunk base cost small relative to the
    # every-chunk single-victim GC dispatch the section is measuring.
    return geometry.SimConfig(
        blocks_per_plane=64,
        slots_per_block=256,
        n_logical=57_344,
        chunk=256,
        migrate_pages_per_chunk=64,
        max_conversions_per_chunk=4,
        gc_free_threshold=24,
        gc_victims_per_pass=8,
        policy=geometry.BASELINE,
        initial_pe=500,
    )


def channel_contention_config(tiny: bool):
    """Geometry for the ``channel_contention`` section: every die on one
    channel (1 x 4) under ``chan_model="lattice"``, so page transfers from
    four concurrently-sensing dies serialize on a single bus. BASELINE
    policy keeps the section a pure pricing of the two-resource tandem
    recursion (no conversion/GC work in the loop)."""
    from repro.ssdsim import geometry

    if tiny:
        return geometry.tiny_config(
            n_channels=1, luns_per_channel=4, policy=geometry.BASELINE,
            initial_pe=500, chan_model="lattice",
        )
    return geometry.SimConfig(
        n_channels=1,
        luns_per_channel=4,
        blocks_per_plane=64,
        slots_per_block=256,
        n_logical=32_768,
        chunk=512,
        migrate_pages_per_chunk=64,
        max_conversions_per_chunk=4,
        gc_free_threshold=4,
        policy=geometry.BASELINE,
        initial_pe=500,
        chan_model="lattice",
    )


def _sections(tiny: bool, n_requests: int):
    """name -> (cfg, trace, has_writes). ``gc_pressure`` runs a write-heavy
    mixed trace with Zipf-skewed overwrites (concentrated invalidation makes
    worthwhile GC victims) against the small-free-pool geometry."""
    import dataclasses

    from repro.ssdsim import workload

    cfg = bench_config(tiny)
    gc_cfg = gc_pressure_config(tiny)
    # same geometry + trace as ``gc_pressure`` under the lifespan-aware GC
    # victim objective (DESIGN.md §2E): the pair prices the pluggable
    # scorer + wear telemetry against the pinned min-valid default
    gcl_cfg = dataclasses.replace(gc_cfg, gc_objective="lifespan")
    cc_cfg = channel_contention_config(tiny)
    mixed_trace = workload.mixed_trace(cfg, n_requests, 1.2, read_frac=0.7,
                                       seed=1)
    # same geometry + trace as ``mixed`` with every instrument on: the pair
    # prices the observability layer (DESIGN.md §7.4) and the regression
    # gate's ``mixed`` row doubles as the obs_level="off" zero-cost guard
    obs_cfg = dataclasses.replace(cfg, obs_level="full")
    # same geometry + trace as ``mixed`` with the fault model armed: the pair
    # prices the fault-injection layer (DESIGN.md §2D) — counter-hash draws,
    # the collapsed-retry read path, and the re-placement/retirement scatters
    flt_cfg = dataclasses.replace(
        cfg,
        max_read_retries=FAULT_MAX_READ_RETRIES,
        prog_fail_rate=FAULT_PROG_FAIL_RATE,
        erase_fail_rate=FAULT_ERASE_FAIL_RATE,
        fault_seed=FAULT_SEED,
    )
    # same geometry + trace with the whole wear-correlated reliability model
    # armed on top of mixed_faults (wear curve, read faults, parity rebuild,
    # finite spares): the flt/wear pair prices the wear-model increment
    wear_cfg = dataclasses.replace(
        flt_cfg,
        read_fail_rate=WEAROUT_READ_FAIL_RATE,
        fault_wear_slope=WEAROUT_WEAR_SLOPE,
        parity_rebuild=True,
        spare_blocks=WEAROUT_SPARE_BLOCKS,
    )
    return {
        "read_only": (
            cfg, workload.zipf_read_trace(cfg, n_requests, 1.2, seed=1), False),
        "mixed": (cfg, mixed_trace, True),
        "mixed_obs_full": (obs_cfg, mixed_trace, True),
        "mixed_faults": (flt_cfg, mixed_trace, True),
        "wearout": (wear_cfg, mixed_trace, True),
        "gc_pressure": (
            gc_cfg,
            workload.mixed_trace(gc_cfg, n_requests, 1.2, seed=1,
                                 read_frac=GC_PRESSURE_READ_FRAC,
                                 write_theta=GC_PRESSURE_WRITE_THETA),
            True),
        "gc_lifespan": (
            gcl_cfg,
            workload.mixed_trace(gcl_cfg, n_requests, 1.2, seed=1,
                                 read_frac=GC_PRESSURE_READ_FRAC,
                                 write_theta=GC_PRESSURE_WRITE_THETA),
            True),
        "channel_contention": (
            cc_cfg,
            workload.zipf_read_trace(
                cc_cfg, n_requests, CHAN_CONTENTION_READ_THETA, seed=1,
                arrival_rate=CHAN_CONTENTION_RATE_IOPS),
            False),
    }


class _profiler:
    """``jax.profiler.trace`` around the timed section when ``--profile``
    asks for it; a no-op otherwise. Profiling support varies by backend and
    jax build, so failure to start downgrades to a warning — the benchmark
    numbers must never depend on the profiler being available."""

    def __init__(self, profile_dir, section):
        self.dir = (str(Path(profile_dir) / section) if profile_dir else None)
        self.active = False

    def __enter__(self):
        if self.dir:
            try:
                jax.profiler.start_trace(self.dir)
                self.active = True
            except Exception as e:  # unsupported backend/build
                print(f"# profiler unavailable, continuing unprofiled: {e}")
        return self

    def __exit__(self, *exc):
        if self.active:
            try:
                jax.profiler.stop_trace()
                print(f"# wrote profiler trace to {self.dir}")
            except Exception as e:
                print(f"# profiler stop failed: {e}")
        return False


def bench_engine(tiny: bool, n_requests: int, repeats: int, profile_dir=None):
    """Yield (name, value, unit) rows; compile time via AOT lower/compile so
    the steady-state timing loop never pays tracing cost."""
    from repro.ssdsim import engine

    for wl, (cfg, trace, has_writes) in _sections(tiny, n_requests).items():
        lpns = jnp.asarray(trace["lpn"], jnp.int32)
        ops = jnp.asarray(trace["op"], jnp.int32)
        n_chunks = lpns.shape[0]

        t0 = time.perf_counter()
        if "arrival_ms" in trace:  # open-loop section (arrival model)
            arr = jnp.asarray(trace["arrival_ms"], jnp.float32)
            compiled = engine._run_open_jit.lower(
                cfg, lpns, ops, arr, has_writes).compile()
            run = lambda: compiled(lpns, ops, arr)  # noqa: E731
        else:
            compiled = engine._run_jit.lower(cfg, lpns, ops,
                                             has_writes).compile()
            run = lambda: compiled(lpns, ops)  # noqa: E731
        compile_s = time.perf_counter() - t0

        jax.block_until_ready(run())  # warm-up / page in
        with _profiler(profile_dir, wl):
            t0 = time.perf_counter()
            for _ in range(repeats):
                jax.block_until_ready(run())
            dt = (time.perf_counter() - t0) / repeats

        yield f"engine/{wl}/compile_s", compile_s, "s"
        yield f"engine/{wl}/ms_per_chunk", dt / n_chunks * 1e3, "ms"
        yield f"engine/{wl}/chunks_per_sec", n_chunks / dt, "chunks/s"
        yield f"engine/{wl}/requests_per_sec", n_chunks * cfg.chunk / dt, "req/s"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true", help="unit-test geometry (CI smoke)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--out", default=".", metavar="DIR",
                    help="directory for the BENCH_engine.json artifact")
    ap.add_argument("--profile", action="store_true",
                    help="wrap the timed loop in jax.profiler.trace "
                         "(ignored with a warning when unsupported)")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="profiler artifact directory "
                         "(default: <--out>/profile)")
    args = ap.parse_args()

    cfg = bench_config(args.tiny)
    gc_cfg = gc_pressure_config(args.tiny)
    cc_cfg = channel_contention_config(args.tiny)
    n_requests = args.requests or (4 * cfg.chunk if args.tiny else 40 * cfg.chunk)

    profile_dir = None
    if args.profile:
        profile_dir = args.profile_dir or str(Path(args.out) / "profile")

    rows = []
    print("name,value,unit")
    for row in bench_engine(args.tiny, n_requests, args.repeats, profile_dir):
        rows.append(list(row))
        n, v, u = row
        print(f"{n},{v:.4f},{u}", flush=True)

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    doc = {
        "bench": "engine",
        "config": {
            "tiny": args.tiny,
            "n_blocks": cfg.n_blocks,
            "slots_per_block": cfg.slots_per_block,
            "n_logical": cfg.n_logical,
            "chunk": cfg.chunk,
            "policy": cfg.policy,
            "n_requests": n_requests,
            "repeats": args.repeats,
            "gc_pressure": {
                "n_logical": gc_cfg.n_logical,
                "gc_free_threshold": gc_cfg.gc_free_threshold,
                "gc_victims_per_pass": gc_cfg.gc_victims_per_pass,
                "read_frac": GC_PRESSURE_READ_FRAC,
                "write_theta": GC_PRESSURE_WRITE_THETA,
            },
            "gc_lifespan": {
                "gc_objective": "lifespan",
                "gc_alpha": gc_cfg.gc_alpha,
                "gc_beta": gc_cfg.gc_beta,
                "gc_gamma": gc_cfg.gc_gamma,
                "base": "gc_pressure geometry + trace",
            },
            "mixed_faults": {
                "max_read_retries": FAULT_MAX_READ_RETRIES,
                "prog_fail_rate": FAULT_PROG_FAIL_RATE,
                "erase_fail_rate": FAULT_ERASE_FAIL_RATE,
                "fault_seed": FAULT_SEED,
            },
            "wearout": {
                "read_fail_rate": WEAROUT_READ_FAIL_RATE,
                "fault_wear_slope": WEAROUT_WEAR_SLOPE,
                "parity_rebuild": True,
                "spare_blocks": WEAROUT_SPARE_BLOCKS,
                "base": "mixed_faults config + trace",
            },
            "channel_contention": {
                "n_channels": cc_cfg.n_channels,
                "luns_per_channel": cc_cfg.luns_per_channel,
                "chan_model": cc_cfg.chan_model,
                "rate_iops": CHAN_CONTENTION_RATE_IOPS,
                "theta": CHAN_CONTENTION_READ_THETA,
            },
        },
        "rows": rows,
    }
    p = out / "BENCH_engine.json"
    p.write_text(json.dumps(doc, indent=1, sort_keys=True))
    print(f"# wrote {p}")


if __name__ == "__main__":
    main()
