"""Engine-throughput benchmark (DESIGN.md §2A): chunks/sec for the simulator
hot path, measured separately for read-only and mixed read/write traces.

The paper's headline figures (13-18) come from mixed traces, so this script
is the regression guard for the vectorized write path and the fused reclaim
pass: it reports steady-state chunks/sec and wall-clock per chunk (compile
excluded, measured separately) and emits a ``BENCH_engine.json`` artifact in
the same ``name,value,unit`` row format as the rest of the harness.

  PYTHONPATH=src python -m benchmarks.engine_bench [--tiny] [--repeats N]
      [--out DIR]

``--tiny`` runs the unit-test geometry (CI smoke); the default is a mid-size
geometry large enough that per-chunk work dominates dispatch overhead.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp


def bench_config(tiny: bool):
    from repro.ssdsim import geometry

    if tiny:
        return geometry.tiny_config(policy=geometry.RARO, initial_pe=500)
    return geometry.SimConfig(
        blocks_per_plane=64,  # 256 blocks
        slots_per_block=256,
        n_logical=32_768,  # half the device, like the paper's 8 GiB / 16 GiB
        chunk=512,
        migrate_pages_per_chunk=64,
        max_conversions_per_chunk=4,
        gc_free_threshold=4,
        policy=geometry.RARO,
        initial_pe=500,
    )


def _traces(cfg, n_requests: int):
    from repro.ssdsim import workload

    return {
        "read_only": (workload.zipf_read_trace(cfg, n_requests, 1.2, seed=1), False),
        "mixed": (workload.mixed_trace(cfg, n_requests, 1.2, read_frac=0.7, seed=1), True),
    }


def bench_engine(cfg, n_requests: int, repeats: int):
    """Yield (name, value, unit) rows; compile time via AOT lower/compile so
    the steady-state timing loop never pays tracing cost."""
    from repro.ssdsim import engine

    for wl, (trace, has_writes) in _traces(cfg, n_requests).items():
        lpns = jnp.asarray(trace["lpn"], jnp.int32)
        ops = jnp.asarray(trace["op"], jnp.int32)
        n_chunks = lpns.shape[0]

        t0 = time.perf_counter()
        compiled = engine._run_jit.lower(cfg, lpns, ops, has_writes).compile()
        compile_s = time.perf_counter() - t0

        jax.block_until_ready(compiled(lpns, ops))  # warm-up / page in
        t0 = time.perf_counter()
        for _ in range(repeats):
            jax.block_until_ready(compiled(lpns, ops))
        dt = (time.perf_counter() - t0) / repeats

        yield f"engine/{wl}/compile_s", compile_s, "s"
        yield f"engine/{wl}/ms_per_chunk", dt / n_chunks * 1e3, "ms"
        yield f"engine/{wl}/chunks_per_sec", n_chunks / dt, "chunks/s"
        yield f"engine/{wl}/requests_per_sec", n_chunks * cfg.chunk / dt, "req/s"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true", help="unit-test geometry (CI smoke)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--out", default=".", metavar="DIR",
                    help="directory for the BENCH_engine.json artifact")
    args = ap.parse_args()

    cfg = bench_config(args.tiny)
    n_requests = args.requests or (4 * cfg.chunk if args.tiny else 40 * cfg.chunk)

    rows = []
    print("name,value,unit")
    for row in bench_engine(cfg, n_requests, args.repeats):
        rows.append(list(row))
        n, v, u = row
        print(f"{n},{v:.4f},{u}", flush=True)

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    doc = {
        "bench": "engine",
        "config": {
            "tiny": args.tiny,
            "n_blocks": cfg.n_blocks,
            "slots_per_block": cfg.slots_per_block,
            "n_logical": cfg.n_logical,
            "chunk": cfg.chunk,
            "policy": cfg.policy,
            "n_requests": n_requests,
            "repeats": args.repeats,
        },
        "rows": rows,
    }
    p = out / "BENCH_engine.json"
    p.write_text(json.dumps(doc, indent=1, sort_keys=True))
    print(f"# wrote {p}")


if __name__ == "__main__":
    main()
