"""Latency-under-load benchmark (DESIGN.md §2C): read-latency hockey-stick
curves from the open-loop arrival engine.

A retry-heavy read-disturb trace is replayed open-loop at a Poisson base
rate calibrated to the device's measured closed-loop throughput, then swept
over offered-load multipliers (``RunKnobs.arrival_scale``) so every load
point of a policy's curve runs in one compiled batch. Runs use the full
``chan_model="lattice"`` resource model (die sense + shared channel bus),
so the curves price transfer queueing on the ONFI channels as well as die
occupancy — the knee sits left of where the legacy one-clock-per-LUN model
put it at the same geometry. The emitted ``BENCH_latency.json`` carries,
per policy and load point, offered IOPS, achieved IOPS, mean/p50/p99/p999
read latency and mean queueing delay — plus the closed-loop reference run,
whose p99 the open-loop tail must exceed at high offered load (the
queueing the closed-loop engine cannot see).

  PYTHONPATH=src python -m benchmarks.latency_bench [--smoke] [--out DIR]
      [--requests N] [--scales 0.25,0.5,...]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

DEFAULT_SCALES = (0.25, 0.5, 0.8, 1.0, 1.5, 2.5, 4.0)
SMOKE_SCALES = (0.25, 1.0, 4.0)

_METRICS = (
    ("offered_iops", "IOPS"),
    ("iops", "IOPS"),
    ("mean_read_latency_us", "us"),
    ("read_lat_p50_us", "us"),
    ("read_lat_p99_us", "us"),
    ("read_lat_p999_us", "us"),
    ("read_queue_delay_us", "us"),
)


def bench_latency(cfg, n_requests: int, scales, threads: int = 4):
    """Run closed-loop references + the open-loop load sweep.

    Returns (rows, curves, base_rate_iops): harness-style (name, value,
    unit) rows, a per-policy dict of aligned metric lists for plotting, and
    the calibrated base Poisson arrival rate.
    """
    from repro.experiments import registry, sweep
    from repro.ssdsim import engine, geometry

    # closed-loop reference per policy; baseline throughput calibrates the
    # base arrival rate so scale 1.0 sits near the knee of the curve
    trace = registry.build("read_disturb_hammer", cfg, n_requests, seed=0)
    rows, closed = [], {}
    for pol in (geometry.BASELINE, geometry.RARO):
        pcfg = cfg.with_policy(pol)
        s, _ = engine.run(pcfg, trace)
        m = engine.summarize(s, pcfg, threads=threads)
        closed[pol] = m
        pname = geometry.POLICY_NAMES[pol]
        for k, u in _METRICS[1:]:
            rows.append((f"latency/{pname}/closed/{k}", float(m[k]), u))
    base_rate = max(closed[geometry.BASELINE]["iops"], 1.0)

    spec = sweep.SweepSpec(
        scenario="hammer_openloop",
        n_requests=n_requests,
        policies=(geometry.BASELINE, geometry.RARO),
        initial_pe=(cfg.initial_pe,),
        seeds=(0,),
        arrival_scale=tuple(scales),
        scenario_kw=(("rate_iops", base_rate),),
        base=cfg,
    )
    results = sweep.run_sweep(spec, threads=threads)

    curves = {}
    for res in results:
        run = res["run"]
        pname, scale = run["policy"], run["arrival_scale"]
        res["offered_iops"] = base_rate * scale
        c = curves.setdefault(pname, {k: [] for k, _ in _METRICS})
        c.setdefault("arrival_scale", []).append(scale)
        for k, u in _METRICS:
            c[k].append(float(res[k]))
            rows.append((f"latency/{pname}/load{scale:g}/{k}", float(res[k]), u))
    for pol, m in closed.items():
        curves[geometry.POLICY_NAMES[pol]]["closed_p99_us"] = float(
            m["read_lat_p99_us"]
        )
    return rows, curves, base_rate


def main() -> None:
    import dataclasses

    from benchmarks.engine_bench import bench_config

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny geometry + 3 load points (CI)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--scales", default=None,
                    help="comma-separated offered-load multipliers")
    ap.add_argument("--out", default=".", metavar="DIR",
                    help="directory for the BENCH_latency.json artifact")
    args = ap.parse_args()

    # same geometry as engine_bench, but with the hierarchical timing
    # lattice on so the curves include channel-bus queueing
    cfg = dataclasses.replace(bench_config(args.smoke), chan_model="lattice")
    n_requests = args.requests or (4 * cfg.chunk if args.smoke else 40 * cfg.chunk)
    scales = (
        tuple(float(x) for x in args.scales.split(","))
        if args.scales else (SMOKE_SCALES if args.smoke else DEFAULT_SCALES)
    )

    rows, curves, base_rate = bench_latency(cfg, n_requests, scales)
    print("name,value,unit")
    for n, v, u in rows:
        print(f"{n},{v:.4f},{u}", flush=True)

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    doc = {
        "bench": "latency",
        "config": {
            "smoke": args.smoke,
            "n_blocks": cfg.n_blocks,
            "slots_per_block": cfg.slots_per_block,
            "n_logical": cfg.n_logical,
            "chunk": cfg.chunk,
            "initial_pe": cfg.initial_pe,
            "n_requests": n_requests,
            "base_rate_iops": base_rate,
            "arrival_scales": list(scales),
            "chan_model": cfg.chan_model,
            "n_channels": cfg.n_channels,
            "luns_per_channel": cfg.luns_per_channel,
            "channel_mb_s": cfg.channel_mb_s,
        },
        "curves": curves,
        "rows": [list(r) for r in rows],
    }
    p = out / "BENCH_latency.json"
    p.write_text(json.dumps(doc, indent=1, sort_keys=True))
    print(f"# wrote {p}")


if __name__ == "__main__":
    main()
