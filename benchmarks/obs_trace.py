"""Observability smoke/export CLI (DESIGN.md §7.4): run a mixed workload
with every instrument on, decode the in-scan accumulators, and emit

- ``trace_obs.json`` — Chrome trace-event JSON (load in ui.perfetto.dev or
  ``chrome://tracing``): one track per die of relocation slices, one bus
  track per channel of companion transfer slices + counter tracks for the
  windowed time series;
- ``BENCH_obs.json`` — harness-style rows (per-mode p99 tail attribution,
  event totals) plus the full tail-attribution and conversion-event tables
  the report renderer formats.

  PYTHONPATH=src python -m benchmarks.obs_trace [--tiny] [--open-loop]
      [--requests N] [--out DIR]

``--tiny`` is the CI smoke (unit-test geometry); ``--open-loop`` attaches
Poisson arrivals so the queue component is non-zero.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
from pathlib import Path

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="unit-test geometry (CI smoke)")
    ap.add_argument("--open-loop", action="store_true",
                    help="Poisson arrivals (exercises the queue component)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--arrival-rate", type=float, default=8000.0,
                    help="open-loop offered load (requests/sec)")
    ap.add_argument("--out", default=".", metavar="DIR")
    args = ap.parse_args()

    import jax

    from benchmarks.engine_bench import bench_config
    from repro.core import modes
    from repro.ssdsim import engine, obs, trace_export, workload

    base = bench_config(args.tiny)
    cfg = dataclasses.replace(
        base, obs_level="full", obs_event_capacity=4096,
        obs_windows=128 if not args.tiny else 32,
    )
    n_requests = args.requests or (
        16 * cfg.chunk if args.tiny else 40 * cfg.chunk
    )
    trace = workload.mixed_trace(
        cfg, n_requests, 1.2, read_frac=0.7, seed=1,
        arrival_rate=args.arrival_rate if args.open_loop else None,
    )
    s, _ = engine.run(cfg, trace)
    s = jax.device_get(s)  # decoders run host-side on numpy leaves

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    trace_path = trace_export.write_chrome_trace(s, cfg, out / "trace_obs.json")

    attrib = obs.tail_attribution(s, cfg)
    records, total, dropped = obs.decode_events(s, cfg)
    by_reason: dict[str, dict] = {}
    for r in records:
        d = by_reason.setdefault(r["reason_name"], {"events": 0, "pages": 0})
        d["events"] += 1
        d["pages"] += r["pages"]
    mat = obs.event_conversion_matrix(records)

    rows = []
    print("name,value,unit")
    for mode, a in attrib.items():
        for comp, share in a["component_share"].items():
            rows.append([f"obs/{mode}/p99_tail_{comp}_share", share,
                         "fraction"])
        rows.append([f"obs/{mode}/p99_tail_reads", a["tail_reads"], "reads"])
    rows.append(["obs/events/total", float(total), "events"])
    rows.append(["obs/events/dropped", float(dropped), "events"])
    for n, v, u in rows:
        print(f"{n},{v:.4f},{u}", flush=True)

    doc = {
        "bench": "obs",
        "config": {
            "tiny": args.tiny,
            "open_loop": args.open_loop,
            "n_requests": n_requests,
            "obs_event_capacity": cfg.obs_event_capacity,
            "obs_windows": cfg.obs_windows,
            "obs_window_ms": cfg.obs_window_ms,
        },
        "rows": rows,
        "tail_attribution": attrib,
        "events_by_reason": by_reason,
        "conversion_matrix": mat.tolist(),
        "mode_names": list(modes.MODE_NAMES),
        "n_conversions": np.asarray(s.n_conversions).tolist(),
    }
    p = out / "BENCH_obs.json"
    p.write_text(json.dumps(doc, indent=1, sort_keys=True))
    print(f"# wrote {trace_path}")
    print(f"# wrote {p}")


if __name__ == "__main__":
    main()
