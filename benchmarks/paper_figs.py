"""Benchmarks reproducing each figure/table of the paper from the
simulator. Each function returns a list of (name, value, unit) rows and is
invoked by benchmarks.run."""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import modes, retry
from repro.ssdsim import engine, geometry, state as st, workload


def _force_mode(s, cfg, mode):
    """Re-type all data blocks to ``mode`` (motivation experiments read a
    device fully programmed in one mode). Data is laid out densely, so any
    slot beyond pages_per_block(mode) is remapped into extra blocks."""
    ppb = int(geometry.pages_per_block(cfg)[mode])
    spb = cfg.slots_per_block
    L = cfg.n_logical
    lpn = jnp.arange(L, dtype=jnp.int32)
    blk = lpn // ppb
    off = lpn % ppb
    slot = blk * spb + off
    n_blocks_used = int(-(-L // ppb))
    assert n_blocks_used <= cfg.n_blocks, "working set too big for this mode"
    p2l = jnp.full((cfg.n_slots,), -1, jnp.int32).at[slot].set(lpn)
    bidx = jnp.arange(cfg.n_blocks)
    used = bidx < n_blocks_used
    return s._replace(
        l2p=slot,
        p2l=p2l,
        block_mode=jnp.full((cfg.n_blocks,), mode, jnp.int32),
        block_state=jnp.where(used, st.FULL, st.FREE).astype(jnp.int32),
        block_next=jnp.where(used, ppb, 0).astype(jnp.int32),
        block_valid=jnp.where(used, ppb, 0).astype(jnp.int32),
    )


def fig2_mode_read_perf(n_requests=60_000):
    """Fig. 2: random/seq read performance of SLC vs TLC vs QLC devices."""
    rows = []
    byte_per_req = 16 * 1024
    for mode in (modes.SLC, modes.TLC, modes.QLC):
        for kind in ("rand", "seq"):
            cfg = geometry.SimConfig(policy=geometry.BASELINE, initial_pe=50,
                                     device_age_h=1.0, n_logical=131_072)
            tr = (workload.uniform_read_trace(cfg, n_requests, seed=1)
                  if kind == "rand" else workload.seq_read_trace(cfg, n_requests))
            s0 = st.init_state(cfg)
            s0 = _force_mode(s0, cfg, mode)
            import jax
            from jax import lax

            def body(s, x):
                return engine.step_chunk(s, x, cfg, False)

            s, _ = jax.jit(lambda s, l, o: lax.scan(body, s, (l, o)))(
                s0, jnp.asarray(tr["lpn"]), jnp.asarray(tr["op"]))
            m = engine.summarize(s, cfg)
            bw = m["iops"] * byte_per_req / 1e6
            rows.append((f"fig2/{modes.MODE_NAMES[mode]}/{kind}_read", bw, "MB/s"))
    # degradation headline (paper: QLC ~63.6% below SLC on seq 128K)
    slc = [r for r in rows if "SLC/seq" in r[0]][0][1]
    qlc = [r for r in rows if "QLC/seq" in r[0]][0][1]
    rows.append(("fig2/qlc_vs_slc_seq_degradation", 100 * (1 - qlc / slc), "%"))
    return rows


def fig3_4_retry_impact():
    """Figs. 3/4: bandwidth vs retry count for TLC and QLC (16KB reads)."""
    rows = []
    for mode, name in ((modes.TLC, "fig3/TLC"), (modes.QLC, "fig4/QLC")):
        base = float(retry.read_latency_us(mode, 0))
        for n in (0, 1, 2, 4, 6, 10, 16):
            lat = float(retry.read_latency_us(mode, n))
            rows.append((f"{name}/retry{n}_bw_drop", 100 * (1 - base / lat), "%"))
    return rows


def fig5_6_retry_distribution(n_pages=20_000):
    """Figs. 5/6: per-stage retry distributions under workload stress."""
    rows = []
    pages = jnp.arange(n_pages)
    rs = np.random.RandomState(0)
    for mode, nm in ((modes.TLC, "fig5/TLC"), (modes.QLC, "fig6/QLC")):
        for stage, (lo, hi) in (("young", (0, 333)), ("middle", (334, 666)),
                                ("old", (667, 1000))):
            cyc = rs.uniform(lo, hi, n_pages)
            n = np.asarray(retry.page_retries(mode, cyc, 100.0, 2000.0, pages))
            rows.append((f"{nm}/{stage}/median", float(np.median(n)), "retries"))
            rows.append((f"{nm}/{stage}/p95", float(np.percentile(n, 95)), "retries"))
            rows.append((f"{nm}/{stage}/max_share", 100 * float(np.mean(n == n.max())), "%"))
    return rows


def fig13_16_policy_comparison(n_requests=200_000, thetas=(1.2, 1.5), threads=(4, 1)):
    """Figs. 13-16: IOPS + capacity change, 3 policies x 3 stages x zipf x
    threads. The paper's headline claims live here."""
    rows = []
    for th in threads:
        for theta in thetas:
            for pe, stage in ((166, "young"), (500, "middle"), (833, "old")):
                res = {}
                for pol in (geometry.BASELINE, geometry.HOTNESS, geometry.RARO):
                    cfg = geometry.SimConfig(policy=pol, initial_pe=pe, device_age_h=24.0)
                    tr = workload.zipf_read_trace(cfg, n_requests, theta, seed=1)
                    s, _ = engine.run(cfg, tr)
                    res[pol] = engine.summarize(s, cfg, threads=th)
                b, h, r = res[geometry.BASELINE], res[geometry.HOTNESS], res[geometry.RARO]
                tag = f"fig13-16/t{th}/zipf{theta}/{stage}"
                rows += [
                    (f"{tag}/raro_vs_base_iops", r["iops"] / b["iops"], "x"),
                    (f"{tag}/raro_vs_hotness_iops", r["iops"] / h["iops"], "x"),
                    (f"{tag}/hotness_cap_loss", h["capacity_loss_gib"] * 1024, "MiB"),
                    (f"{tag}/raro_cap_loss", r["capacity_loss_gib"] * 1024, "MiB"),
                    (f"{tag}/cap_loss_saving",
                     100 * (1 - r["capacity_loss_gib"] / max(h["capacity_loss_gib"], 1e-9)), "%"),
                ]
    return rows


def fig17_18_sensitivity(n_requests=120_000, theta=1.2):
    """Figs. 17/18: R2 threshold sweep per wear stage."""
    rows = []
    sweeps = {166: (4, 5, 7, 9), 500: (5, 7, 9, 12), 833: (9, 11, 13, 16)}
    for pe, r2s in sweeps.items():
        stage = modes.STAGE_NAMES[int(modes.stage_of(pe))]
        for r2 in r2s:
            cfg = geometry.SimConfig(policy=geometry.RARO, initial_pe=pe,
                                     device_age_h=24.0, r2_override=r2)
            tr = workload.zipf_read_trace(cfg, n_requests, theta, seed=1)
            s, _ = engine.run(cfg, tr)
            m = engine.summarize(s, cfg)
            rows.append((f"fig17/{stage}/R2={r2}/iops", m["iops"], "IOPS"))
            rows.append((f"fig18/{stage}/R2={r2}/cap_loss",
                         m["capacity_loss_gib"] * 1024, "MiB"))
    return rows
