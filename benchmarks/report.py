"""Aggregate the committed ``BENCH_*.json`` artifacts into markdown tables —
the first cut of the reporting layer (ROADMAP: "nothing plots/aggregates it
yet").

Three sections, one per artifact family:

- **engine** (``BENCH_engine.json``): chunks/sec per workload section across
  every measurement key in the artifact (top-level rows, ``tiny_baseline``,
  plus the committed interleaved A/B records like ``dedup_fix`` /
  ``gc_fusion`` with their primitive timings);
- **latency** (``BENCH_latency.json``): the hockey-stick table — offered
  load vs achieved IOPS and p50/p99 latency per policy curve;
- **sweep** (``BENCH_sweep.json``): 1-vs-N device scaling rows.

Output goes to stdout and, when ``--summary PATH`` or
``$GITHUB_STEP_SUMMARY`` is set, is appended there (the CI step renders the
committed artifacts into the job summary).

  PYTHONPATH=src python -m benchmarks.report [--dir benchmarks] [--summary PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path


def _fmt(v: float) -> str:
    return f"{v:,.1f}" if abs(v) >= 10 else f"{v:.3g}"


def _rows_by_section(rows, suffix: str) -> dict[str, float]:
    out = {}
    for name, value, _unit in rows:
        if name.endswith(suffix):
            out[name.split("/")[1]] = float(value)
    return out


def engine_report(doc: dict) -> list[str]:
    """Throughput trend across the artifact's measurement keys + committed
    A/B (before/after) records."""
    keys = {"full geometry": doc}
    if "tiny_baseline" in doc:
        keys["tiny (CI gate baseline)"] = doc["tiny_baseline"]
    sections: list[str] = []
    for k in keys.values():
        for s in _rows_by_section(k.get("rows", []), "/chunks_per_sec"):
            if s not in sections:
                sections.append(s)
    lines = [
        "### Engine throughput (chunks/sec)",
        "",
        "| measurement | " + " | ".join(sections) + " |",
        "|---|" + "---:|" * len(sections),
    ]
    for label, sub in keys.items():
        by = _rows_by_section(sub.get("rows", []), "/chunks_per_sec")
        lines.append(
            f"| {label} | "
            + " | ".join(_fmt(by[s]) if s in by else "—" for s in sections)
            + " |"
        )
    # committed interleaved A/B records (dedup_fix, gc_fusion, ...)
    for key, rec in doc.items():
        if not (isinstance(rec, dict) and "change" in rec):
            continue
        lines += ["", f"**{key}** — {rec['change']}", ""]
        ab = {}
        for k2, v2 in rec.items():
            if k2.startswith("engine_chunks_per_sec_interleaved_median"):
                ab.update(v2)
        if ab:
            lines += ["| section | before | after | speedup |",
                      "|---|---:|---:|---:|"]
            for s, v in ab.items():
                lines.append(
                    f"| {s} | {_fmt(v['before'])} | {_fmt(v['after'])} "
                    f"| {v['after'] / v['before']:.2f}x |"
                )
        prim = rec.get("primitive_us_per_call", {})
        if prim:
            lines += ["", "| primitive | µs/call |", "|---|---:|"]
            lines += [f"| {n} | {_fmt(v)} |" for n, v in prim.items()]
    return lines


def latency_report(doc: dict) -> list[str]:
    """Hockey-stick: offered load vs achieved IOPS / latency per policy."""
    lines = ["### Latency vs offered load (open loop)"]
    for policy, c in doc.get("curves", {}).items():
        lines += [
            "",
            f"**{policy}** (closed-loop p99 "
            f"{_fmt(c.get('closed_p99_us', float('nan')))} µs)",
            "",
            "| arrival scale | offered IOPS | achieved IOPS | mean µs "
            "| p50 µs | p99 µs | queue µs |",
            "|---:|---:|---:|---:|---:|---:|---:|",
        ]
        for i, sc in enumerate(c["arrival_scale"]):
            lines.append(
                f"| {sc:g} | {_fmt(c['offered_iops'][i])} "
                f"| {_fmt(c['iops'][i])} "
                f"| {_fmt(c['mean_read_latency_us'][i])} "
                f"| {_fmt(c['read_lat_p50_us'][i])} "
                f"| {_fmt(c['read_lat_p99_us'][i])} "
                f"| {_fmt(c['read_queue_delay_us'][i])} |"
            )
    return lines


def sweep_report(doc: dict) -> list[str]:
    lines = [
        "### Sharded sweep scaling",
        "",
        "| metric | value | unit |",
        "|---|---:|---|",
    ]
    lines += [f"| `{n}` | {_fmt(float(v))} | {u} |"
              for n, v, u in doc.get("rows", [])]
    if doc.get("note"):
        lines += ["", f"> {doc['note']}"]
    return lines


def obs_report(doc: dict) -> list[str]:
    """Observability readout (DESIGN.md §7.4): per-mode p99 tail latency
    attribution and the decoded conversion-event summary."""
    lines = [
        "### Latency attribution (p99 tail, per source mode)",
        "",
        "| mode | tail reads | tail edge µs | queue | sense | retry "
        "| transfer |",
        "|---|---:|---:|---:|---:|---:|---:|",
    ]
    for mode, a in doc.get("tail_attribution", {}).items():
        sh = a["component_share"]
        lines.append(
            f"| {mode} | {_fmt(a['tail_reads'])} | {_fmt(a['tail_edge_us'])} "
            f"| {sh['queue']:.1%} | {sh['sense']:.1%} | {sh['retry']:.1%} "
            f"| {sh['transfer']:.1%} |"
        )
    by_reason = doc.get("events_by_reason", {})
    if by_reason:
        lines += [
            "",
            "### Conversion / relocation events",
            "",
            "| trigger | events | valid pages moved |",
            "|---|---:|---:|",
        ]
        for reason, d in sorted(by_reason.items()):
            lines.append(
                f"| {reason} | {d['events']} | {_fmt(float(d['pages']))} |"
            )
    mat = doc.get("conversion_matrix")
    names = doc.get("mode_names", [])
    if mat and names:
        lines += [
            "",
            "**Conversions (from → to, decoded from the event ring)**",
            "",
            "| from \\ to | " + " | ".join(names) + " |",
            "|---|" + "---:|" * len(names),
        ]
        for name, row in zip(names, mat):
            lines.append(
                f"| {name} | " + " | ".join(_fmt(float(v)) for v in row) + " |"
            )
    return lines


def endurance_report(doc: dict) -> list[str]:
    """Endurance frontier (DESIGN.md §2E): read-p99 vs WAF vs projected
    lifetime per (policy, GC objective, wear stage) — the multi-objective
    trade-off RARO claims to win. Column units come from the single
    metrics-schema registry."""
    try:
        from repro.ssdsim import metrics_schema
        u = metrics_schema.units()
    except ImportError:  # report must stay renderable without PYTHONPATH=src
        u = {}
    cfg = doc.get("config", {})
    lines = [
        "### Endurance frontier (read p99 vs WAF vs lifetime)",
        "",
        f"`{cfg.get('scenario', '?')}` × {cfg.get('n_runs', '?')} runs; "
        f"lifespan scorer α={cfg.get('gc_alpha', '?')} "
        f"β={cfg.get('gc_beta', '?')} γ={cfg.get('gc_gamma', '?')}",
        "",
        f"| policy | GC objective | wear (P/E₀) "
        f"| read p99 ({u.get('read_lat_p99_us', 'us')}) "
        f"| WAF ({u.get('waf', 'ratio')}) "
        f"| P/E var ({u.get('pe_variance', 'cycles^2')}) "
        f"| lifetime ({u.get('lifetime_years', 'years')}) "
        f"| cap loss ({u.get('capacity_loss_gib', 'GiB')}) |",
        "|---|---|---:|---:|---:|---:|---:|---:|",
    ]
    for p in doc.get("frontier", []):
        lines.append(
            f"| {p['policy']} | {p['gc_objective']} | {p['initial_pe']} "
            f"| {_fmt(p['read_lat_p99_us'])} | {p['waf']:.4f} "
            f"| {_fmt(p['pe_variance'])} | {p['lifetime_years']:.3g} "
            f"| {_fmt(p['capacity_loss_gib'])} |"
        )
    heads = [(n, v, un) for n, v, un in doc.get("rows", [])
             if "lifespan_vs_min_valid" in n]
    if heads:
        lines += ["", "| lifespan ÷ min-valid | ratio |", "|---|---:|"]
        lines += [f"| `{n}` | {float(v):.4f}{un} |" for n, v, un in heads]
    return lines


def wearout_report(doc: dict) -> list[str]:
    """Wear-correlated failure dashboard (DESIGN.md §2D): reliability
    counters — uncorrectables, rebuilds, data loss, bad blocks, spare drain
    — per (policy, GC objective, wear slope, drive age) cell, plus the
    lifespan-vs-min-valid failure ratios at the worst cell."""
    cfg = doc.get("config", {})
    lines = [
        "### Wear-correlated failure dashboard",
        "",
        f"`{cfg.get('scenario', '?')}` × {cfg.get('n_runs', '?')} runs; "
        f"wear slope ∈ {cfg.get('fault_wear_slope', '?')} "
        f"(power {cfg.get('fault_wear_power', '?')}), "
        f"parity rebuild on, spare pool "
        f"{cfg.get('spare_blocks', '?')} blocks",
        "",
        "| policy | GC objective | wear slope | P/E₀ | uncorr | rebuilds "
        "| data loss | bad blks | spares left | degraded wr | read p99 µs "
        "| WAF |",
        "|---|---|---:|---:|---:|---:|---:|---:|---:|---:|---:|---:|",
    ]
    for p in doc.get("frontier", []):
        lines.append(
            f"| {p['policy']} | {p['gc_objective']} "
            f"| {p['fault_wear_slope']:g} | {p['initial_pe']} "
            f"| {_fmt(p['uncorrectable_reads'])} | {_fmt(p['rebuilds'])} "
            f"| {_fmt(p['data_loss'])} | {_fmt(p['bad_blocks'])} "
            f"| {_fmt(p['spares_remaining'])} | {_fmt(p['degraded_writes'])} "
            f"| {_fmt(p['read_lat_p99_us'])} | {p['waf']:.4f} |"
        )
    heads = [(n, v, un) for n, v, un in doc.get("rows", [])
             if "lifespan_vs_min_valid" in n]
    if heads:
        lines += ["", "**Lifespan ÷ min-valid failure ratios "
                      "(wear-correlated, old device)**", "",
                  "| metric | ratio |", "|---|---:|"]
        lines += [f"| `{n}` | {float(v):.4f}{un} |" for n, v, un in heads]
    return lines


RENDERERS = {
    "BENCH_engine.json": engine_report,
    "BENCH_latency.json": latency_report,
    "BENCH_sweep.json": sweep_report,
    "BENCH_obs.json": obs_report,
    "BENCH_endurance.json": endurance_report,
    "BENCH_wearout.json": wearout_report,
}


def render(bench_dir: Path) -> str:
    parts = ["## Benchmark artifacts", ""]
    found = False
    for fname, fn in RENDERERS.items():
        p = bench_dir / fname
        if not p.exists():
            continue
        found = True
        parts += fn(json.loads(p.read_text())) + [""]
    if not found:
        raise FileNotFoundError(f"no BENCH_*.json artifacts under {bench_dir}")
    return "\n".join(parts)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks", metavar="DIR",
                    help="directory holding the committed BENCH_*.json files")
    ap.add_argument("--summary", default=None, metavar="PATH",
                    help="append the markdown here "
                         "(default: $GITHUB_STEP_SUMMARY when set)")
    args = ap.parse_args(argv)

    md = render(Path(args.dir))
    print(md)
    summary = args.summary or os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(md + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
