"""Roofline analysis per (arch x shape x mesh) cell.

Three terms, all in seconds per step (TPU v5e constants):

  compute    = HLO_FLOPs            / (chips x 197e12 FLOP/s bf16)
  memory     = HBM_traffic_bytes    / (chips x 819e9  B/s)
  collective = wire_bytes_per_chip  / (50e9 B/s per ICI link)

FLOPs/HBM-traffic come from the ANALYTIC per-layer model below (XLA's CPU
cost_analysis counts while-loop bodies ONCE, so compiled totals undercount
scanned layers; tests/test_roofline.py validates the analytic model against
the compiled number on 1-layer variants). Collective payloads come from the
compiled post-SPMD HLO recorded by the dry-run (bytes_once + n_layers x
bytes_looped), with wire factors: all-reduce 2x payload, others 1x.

  PYTHONPATH=src python -m benchmarks.roofline [--mesh single_pod_16x16]
"""

from __future__ import annotations

import argparse
import json
from dataclasses import dataclass
from pathlib import Path

from repro.configs import ARCHS, SHAPES
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.layers import padded_vocab

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/chip
LINK_BW = 50e9  # B/s/link ICI
RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def _mm(m, k, n):
    return 2.0 * m * k * n


@dataclass
class Flops:
    layers: float = 0.0  # all sequence-mixer + ffn layers, fwd
    head: float = 0.0  # embed/logits, fwd
    attn_ctx: float = 0.0  # part of `layers` that is attention-vs-context


def _attn_flops(cfg: ModelConfig, T: float, ctx: float, causal_half: bool) -> float:
    h, dh = cfg.n_heads, cfg.head_dim
    f = 2 * _mm(T, ctx, 1) * h * dh  # scores + PV (each 2*T*ctx*dh per head)
    if cfg.window:
        f = min(f, 2 * _mm(T, min(ctx, cfg.window), 1) * h * dh)
    return f * (0.5 if causal_half else 1.0)


def _dense_layer(cfg: ModelConfig, T: float, ctx: float, causal_half=True) -> float:
    d, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    f = _mm(T, d, h * dh) + 2 * _mm(T, d, hk * dh) + _mm(T, h * dh, d)  # qkvo
    f += _attn_flops(cfg, T, ctx, causal_half)
    if cfg.d_ff:
        f += 3 * _mm(T, d, cfg.d_ff)
    return f


def _mla_layer_attn(cfg: ModelConfig, T: float, ctx: float, causal_half=True) -> float:
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    ql, kl = cfg.q_lora_rank, cfg.kv_lora_rank
    f = _mm(T, d, ql) + _mm(T, ql, h * (dn + dr))  # q path
    f += _mm(T, d, kl + dr) + _mm(T, kl, h * (dn + dv))  # kv path
    f += 2 * _mm(T, ctx, 1) * h * (dn + dr + dv) / 2 * (1 if not causal_half else 0.5) * 2
    f += _mm(T, h * dv, d)
    return f


def _moe_ffn(cfg: ModelConfig, T: float) -> float:
    d = cfg.d_model
    f = _mm(T, d, cfg.n_experts)  # router
    f += 3 * _mm(T * cfg.top_k * cfg.capacity_factor, d, cfg.moe_d_ff)
    if cfg.n_shared_experts:
        f += 3 * _mm(T, d, cfg.moe_d_ff * cfg.n_shared_experts)
    return f


def _ssm_layer(cfg: ModelConfig, T: float) -> float:
    d = cfg.d_model
    di = cfg.expand * d
    if cfg.ssm_kind == "xlstm":
        h = cfg.n_heads
        dh = di // h
        f = _mm(T, d, 2 * di) + 3 * _mm(T, di, di) + _mm(T, di, d)
        f += T * h * (4 * dh * dh)  # outer product + 2 matvecs per step
        return f
    # mamba2
    n = cfg.d_state
    hs = max(di // 64, 1)
    p = di // hs
    f = _mm(T, d, 2 * di + 2 * n + hs) + _mm(T, di, d)
    f += T * hs * p * n * 6  # decay+outer+contract
    return f


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Analytic FLOPs for one step of this cell (global, fwd/bwd folded)."""
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    T = B * S if kind != "decode" else B
    ctx = S
    causal_half = kind != "decode"
    vp = padded_vocab(cfg.vocab)

    if cfg.family in ("dense", "vlm"):
        per_layer = _dense_layer(cfg, T, ctx, causal_half)
        layers = per_layer * cfg.n_layers
    elif cfg.family == "moe":
        attn = (_mla_layer_attn if cfg.mla else
                lambda c, t, x, ch=causal_half: _dense_layer(
                    c.with_(d_ff=0), t, x, ch))(cfg, T, ctx)
        moe_layers = cfg.n_layers - cfg.first_k_dense
        layers = (attn + _moe_ffn(cfg, T)) * moe_layers
        if cfg.first_k_dense:
            layers += (attn + 3 * _mm(T, cfg.d_model, cfg.d_ff)) * cfg.first_k_dense
        if cfg.mtp_depth and kind == "train":
            layers += _dense_layer(cfg, T, ctx, causal_half) + _mm(T, 2 * cfg.d_model, cfg.d_model)
    elif cfg.family == "encdec":
        enc_T = B * cfg.enc_len
        enc = (0.0 if kind == "decode" else
               _dense_layer(cfg, enc_T, cfg.enc_len, causal_half=False) * cfg.n_enc_layers)
        dec_self = _dense_layer(cfg, T, ctx, causal_half)
        dec_cross = (_mm(T, cfg.d_model, cfg.n_heads * cfg.head_dim) * 2
                     + _attn_flops(cfg, T, cfg.enc_len, False))
        layers = enc + (dec_self + dec_cross) * cfg.n_layers
    elif cfg.family == "ssm":
        layers = _ssm_layer(cfg, T) * cfg.n_layers
    else:  # hybrid
        layers = _ssm_layer(cfg, T) * cfg.n_layers
        n_apps = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
        layers += (_dense_layer(cfg, T, ctx, causal_half)) * n_apps

    head = _mm(T, cfg.d_model, vp)

    if kind == "train":
        mult_layers = 4.0 if cfg.remat else 3.0  # fwd + 2x bwd (+ remat fwd)
        total = layers * mult_layers + head * 3.0
    else:
        total = layers + head
    return {"layers_fwd": layers, "head_fwd": head, "total": total}


def model_flops_6nd(cfg: ModelConfig, shape: ShapeConfig, param_bytes: int) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE), D = tokens/step."""
    n_params = param_bytes / 2  # bf16
    if cfg.n_experts:
        # active fraction of expert params + everything else
        d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
        expert_p = 3 * d * f * e * (cfg.n_layers - cfg.first_k_dense)
        active = n_params - expert_p + expert_p * cfg.top_k / e
        n_params = active
    toks = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 3 if shape.kind == "train" else 1  # 6ND counts fwd+bwd; inference 2ND
    return 2.0 * n_params * toks * mult


def hbm_traffic_per_chip(cfg: ModelConfig, shape: ShapeConfig, rec: dict,
                         chips: int, tp: int = 16) -> float:
    """Per-chip per-step HBM bytes (documented model).

    Params are model-sharded only (tp-way): every data-parallel replica
    streams params/tp from its own HBM — so the per-chip param term is
    params/tp, NOT params/chips. Activations/logits/KV shard over all
    chips; a KV cache whose heads/seq cannot use the model axis is
    replicated across it (the seq_shard §Perf iteration removes that).

      train  : (fwd + bwd + remat-fwd) param reads + grad write + 2x f32
               moments r/w + per-layer activation w+r + f32 logits + grad
      prefill: params + KV write + activations
      decode : params(active experts for MoE) + full KV-cache read/step
    """
    B, S = shape.global_batch, shape.seq_len
    pbytes = rec["param_bytes_global"]
    d = cfg.d_model
    vp = padded_vocab(cfg.vocab)
    p_chip = pbytes / tp
    if shape.kind == "train":
        toks = B * S
        act = 2 * toks * d * 2 * cfg.n_layers * 2 / chips  # w+r bf16/layer
        if cfg.xent_chunk:
            logits = 2 * toks * vp * 4 / chips  # live chunk only, r+w once
        else:
            logits = 2 * toks * vp * 4 * 2 / chips  # f32 logits + grad, r/w
        passes = 3 if cfg.remat else 2
        opt = (pbytes / 2) * 4 * 2 * 2 / tp  # m,v f32 read+write (sharded as params)
        grads = pbytes / tp
        return passes * p_chip + grads + opt + act + logits
    if shape.kind == "prefill":
        toks = B * S
        kv = _kv_bytes(cfg, B, S) / chips
        act = 2 * toks * d * 2 * cfg.n_layers / chips
        return p_chip + kv + act
    # decode
    kv_global = _kv_bytes(cfg, B, S)
    kv_sharded_model = (cfg.n_kv_heads % tp == 0) or cfg.mla or rec.get("seq_shard")
    kv = kv_global / chips if kv_sharded_model else kv_global / (chips / tp)
    active = pbytes
    if cfg.n_experts:
        exp = 3 * cfg.d_model * cfg.moe_d_ff * cfg.n_experts * 2 * (cfg.n_layers - cfg.first_k_dense)
        active = pbytes - exp + min(exp, exp * cfg.top_k / cfg.n_experts * max(B / 8, 1))
    return active / tp + kv


def _kv_bytes(cfg: ModelConfig, B: int, S: int) -> float:
    s_eff = min(S, cfg.window) if cfg.window else S
    kv_scale = cfg.kv_bits / 16.0 + (0.25 if cfg.kv_bits < 16 else 0.0)  # + f32 scales/token
    if cfg.family == "ssm":
        di = cfg.expand * cfg.d_model
        h = cfg.n_heads
        return cfg.n_layers * B * (di // h) ** 2 * h * 4  # mLSTM C state f32
    if cfg.family == "hybrid":
        di = cfg.expand * cfg.d_model
        hs = max(di // 64, 1)
        state = cfg.n_layers * B * di * cfg.d_state * 4 / max(hs, 1) * hs / hs
        n_apps = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
        kv = n_apps * B * s_eff * 2 * cfg.n_kv_heads * cfg.head_dim * 2
        return state + kv
    if cfg.mla:
        per_tok = cfg.kv_lora_rank + cfg.rope_head_dim
        return cfg.n_layers * B * s_eff * per_tok * 2
    return cfg.n_layers * B * s_eff * 2 * cfg.n_kv_heads * cfg.head_dim * 2 * kv_scale


def collective_wire_bytes(rec: dict) -> float:
    """Per-chip wire bytes from the dry-run collective table (loop
    multipliers already applied by the dry-run's HLO call-graph parse)."""
    total = 0.0
    for kind, a in rec.get("collectives", {}).items():
        payload = a.get("bytes_total",
                        a.get("bytes_once", 0) + a.get("bytes_looped", 0) * rec["n_layers"])
        factor = 2.0 if kind == "all-reduce" else 1.0
        total += payload * factor
    return total


def analyze(rec: dict) -> dict:
    cfg = ARCHS[rec["arch"]]
    if rec.get("overrides"):
        cfg = cfg.with_(**rec["overrides"])
    shape = SHAPES[rec["shape"]]
    chips = rec["devices"]
    tp = 16
    fl = model_flops(cfg, shape)
    traffic = hbm_traffic_per_chip(cfg, shape, rec, chips, tp)
    wire = collective_wire_bytes(rec)

    t_compute = fl["total"] / (chips * PEAK_FLOPS)
    t_memory = traffic / HBM_BW
    t_coll = wire / LINK_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)
    step = max(terms.values())
    mf = model_flops_6nd(cfg, shape, rec["param_bytes_global"])
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "variants": "+".join(rec.get("variants", [])),
        **{k: float(f"{v:.3e}") for k, v in terms.items()},
        "bottleneck": bottleneck.replace("_s", ""),
        "step_s": float(f"{step:.3e}"),
        "roofline_fraction": round(t_compute / step, 4) if step else 0.0,
        "bw_util_proxy": round(t_memory / step, 4) if step else 0.0,
        "hlo_flops": float(f"{fl['total']:.3e}"),
        "model_flops_6nd": float(f"{mf:.3e}"),
        "useful_ratio": round(mf / fl["total"], 3),
        "hbm_bytes": float(f"{traffic:.3e}"),
        "wire_bytes_per_chip": float(f"{wire:.3e}"),
        "mem_temp_gib_per_chip": round(
            (rec["memory_analysis"].get("temp_size_in_bytes") or 0) / 2**30, 2),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single_pod_16x16")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()

    rows = []
    for f in sorted((RESULTS / args.mesh).glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") != "ok":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "bottleneck": "-", "status": rec.get("status"),
                         "reason": rec.get("reason", "")})
            continue
        rows.append(analyze(rec))

    hdr = ["arch", "shape", "compute_s", "memory_s", "collective_s",
           "bottleneck", "step_s", "roofline_fraction", "bw_util_proxy",
           "useful_ratio", "mem_temp_gib_per_chip"]
    print(",".join(hdr))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in hdr))
    if args.json_out:
        Path(args.json_out).write_text(json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()
