"""Benchmark harness — one section per paper table/figure plus the Layer-B
(TPU) tiered-KV benchmark. Prints ``name,value,unit`` CSV; with
``--artifacts DIR`` every section also writes a ``BENCH_<section>.json``
artifact carrying the same rows (the sweep section additionally writes its
per-run artifacts, as before).

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only SUBSTR]
      [--artifacts DIR]
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path


def _write_section_artifact(out_dir: str, section: str, rows: list) -> Path:
    """One ``BENCH_<section>.json`` mirroring the section's CSV rows — the
    same name/value/unit format the sweep runner emits per run. Non-finite
    values become null so the artifact stays strict RFC-8259 JSON."""
    def _clean(v):
        if isinstance(v, float) and (v != v or v in (float("inf"), float("-inf"))):
            return None
        return v

    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    p = out / f"BENCH_{section}.json"
    p.write_text(
        json.dumps({"section": section, "rows": [[_clean(v) for v in r] for r in rows]},
                   indent=1, sort_keys=True)
    )
    return p


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced request counts")
    ap.add_argument("--only", default=None)
    ap.add_argument("--artifacts", default=None, metavar="DIR",
                    help="write BENCH_*.json artifacts here (one per section, "
                         "plus the sweep section's per-run artifacts)")
    ap.add_argument("--devices", default=None,
                    help="shard the sweep section's run axis across N devices "
                         "('all' = every visible device; default: vmap on one)")
    args = ap.parse_args()

    from benchmarks import paper_figs, sweep_bench, tiered_kv

    q = args.quick
    sections = [
        ("fig2", lambda: paper_figs.fig2_mode_read_perf(20_000 if q else 60_000)),
        ("fig3_4", paper_figs.fig3_4_retry_impact),
        ("fig5_6", paper_figs.fig5_6_retry_distribution),
        ("fig13_16", lambda: paper_figs.fig13_16_policy_comparison(
            60_000 if q else 200_000,
            thetas=(1.2,) if q else (1.2, 1.5),
            threads=(4,) if q else (4, 1))),
        ("fig17_18", lambda: paper_figs.fig17_18_sensitivity(40_000 if q else 120_000)),
        ("sweep", lambda: sweep_bench.sweep_tail_latency(
            24_000 if q else 80_000,
            msr_requests=8_000 if q else 24_000,
            out_dir=args.artifacts,
            devices=args.devices)),
        ("faults", lambda: sweep_bench.sweep_fault_storm(
            12_000 if q else 40_000,
            out_dir=args.artifacts,
            devices=args.devices)),
        # named so `--only sweep` also matches it: the endurance grid is
        # part of the paper's sweep story (read-p99 vs WAF vs lifetime)
        ("endurance_sweep", lambda: sweep_bench.sweep_endurance(
            8_192 if q else 24_576,
            out_dir=args.artifacts,
            devices=args.devices)),
        # named so `--only sweep` also matches it: the wear-correlated
        # failure dashboard (rebuilds / data loss / spare drain)
        ("wearout_sweep", lambda: sweep_bench.sweep_wearout(
            8_192 if q else 24_576,
            out_dir=args.artifacts,
            devices=args.devices)),
        ("tiered_kv", lambda: tiered_kv.kv_policy_comparison(24 if q else 48)),
    ]

    print("name,value,unit")
    ran, failed = 0, []
    for name, fn in sections:
        if args.only and args.only not in name:
            continue
        ran += 1
        t0 = time.time()
        try:
            rows = []
            for row in fn():
                rows.append(row)
                n, v, u = row
                v = f"{v:.4f}" if isinstance(v, float) else v
                print(f"{n},{v},{u}", flush=True)
            if args.artifacts:
                p = _write_section_artifact(args.artifacts, name, rows)
                print(f"# wrote {p}", flush=True)
            print(f"# section {name} took {time.time()-t0:.1f}s", flush=True)
        except Exception as e:  # keep the harness going
            failed.append(name)
            print(f"# section {name} FAILED: {type(e).__name__}: {e}", flush=True)
    if failed:
        print(f"# {len(failed)}/{ran} sections FAILED: {', '.join(failed)}",
              flush=True)
        sys.exit(1)
    print(f"# all {ran} sections passed", flush=True)


if __name__ == "__main__":
    main()
