"""Tail-latency sweep section for the benchmark harness.

Drives repro.experiments end-to-end: a vmapped 8-run grid (2 policies x 2
wear stages x 2 seeds, one jit per policy group) on the read-disturb-hammer
scenario — the workload where retries hurt p99 most — plus a replay of the
bundled MSR-style sample trace. Emits per-run p50/p95/p99 read latency next
to the mean, and the headline raro-vs-baseline p99 ratios the paper's
"diverse workloads" claim rests on.
"""

from __future__ import annotations

import numpy as np

from repro.experiments import sweep
from repro.ssdsim import geometry


def _p99_ratio_rows(results, scenario: str):
    """Geomean-over-seeds baseline/raro p99 ratio per wear stage."""
    rows = []
    stages = sorted({r["run"]["initial_pe"] for r in results})
    for pe in stages:
        by_pol = {}
        for pol in ("baseline", "raro"):
            v = [r["read_lat_p99_us"] for r in results
                 if r["run"]["initial_pe"] == pe and r["run"]["policy"] == pol]
            if v:
                by_pol[pol] = float(np.exp(np.mean(np.log(np.maximum(v, 1e-9)))))
        if len(by_pol) == 2:
            rows.append((f"sweep/{scenario}/pe{pe}/raro_vs_base_p99",
                         by_pol["baseline"] / by_pol["raro"], "x"))
    return rows


def sweep_tail_latency(n_requests=80_000, msr_requests=24_000, out_dir=None):
    base = geometry.SimConfig(device_age_h=24.0)
    rows = []

    hammer = sweep.SweepSpec(
        scenario="read_disturb_hammer",
        n_requests=n_requests,
        policies=(geometry.BASELINE, geometry.RARO),
        initial_pe=(166, 833),
        seeds=(0, 1),
        base=base,
    )
    res = sweep.run_sweep(hammer, verbose=True)
    for r in res:
        rows += sweep.result_rows(r)
    rows += _p99_ratio_rows(res, "read_disturb_hammer")

    # bundled MSR-style trace replayed through the same runner (mixed R/W)
    msr = sweep.SweepSpec(
        scenario="msr_sample",
        n_requests=msr_requests,
        policies=(geometry.BASELINE, geometry.RARO),
        initial_pe=(500,),
        seeds=(0,),
        base=base,
    )
    res_msr = sweep.run_sweep(msr, verbose=True)
    for r in res_msr:
        rows += sweep.result_rows(r)
    rows += _p99_ratio_rows(res_msr, "msr_sample")

    if out_dir is not None:
        paths = sweep.write_artifacts(res + res_msr, out_dir)
        print(f"# wrote {len(paths)} BENCH_*.json artifacts to {out_dir}", flush=True)
    return rows
