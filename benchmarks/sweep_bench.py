"""Sweep benchmark: tail-latency section for the harness plus the
device-sharded scaling benchmark (DESIGN.md §7.3).

Two entry points:

``sweep_tail_latency``  — the ``benchmarks.run --only sweep`` section: a
policy x wear x seed grid on the read-disturb-hammer scenario plus a replay
of the bundled MSR-style sample trace, emitting per-run tail latencies and
the headline raro-vs-baseline p99 ratios.

``main`` (this module as a script) — the sweep *scaling* benchmark: the same
grid executed by the single-device vmapped path and by the device-sharded
``shard_map`` path, timed end to end (dispatch + execute + host summarize),
written to ``BENCH_sweep.json``. The sharded path needs multiple visible
devices; on a CPU-only host fake them with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the CI smoke does)
or pass ``--fake-devices N`` before anything imports jax.

  PYTHONPATH=src python -m benchmarks.sweep_bench [--smoke] [--devices N]
      [--fake-devices N] [--repeats R] [--requests N] [--out DIR]
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np


def _p99_ratio_rows(results, scenario: str):
    """Geomean-over-seeds baseline/raro p99 ratio per wear stage."""
    rows = []
    stages = sorted({r["run"]["initial_pe"] for r in results})
    for pe in stages:
        by_pol = {}
        for pol in ("baseline", "raro"):
            v = [r["read_lat_p99_us"] for r in results
                 if r["run"]["initial_pe"] == pe and r["run"]["policy"] == pol]
            if v:
                by_pol[pol] = float(np.exp(np.mean(np.log(np.maximum(v, 1e-9)))))
        if len(by_pol) == 2:
            rows.append((f"sweep/{scenario}/pe{pe}/raro_vs_base_p99",
                         by_pol["baseline"] / by_pol["raro"], "x"))
    return rows


def sweep_tail_latency(n_requests=80_000, msr_requests=24_000, out_dir=None,
                       devices=None):
    """Tail-latency section rows; ``devices`` forwards to the sweep runner
    (None = single-device vmap, N = shard the run axis across N devices)."""
    from repro.experiments import sweep
    from repro.ssdsim import geometry

    base = geometry.SimConfig(device_age_h=24.0)
    rows = []

    hammer = sweep.SweepSpec(
        scenario="read_disturb_hammer",
        n_requests=n_requests,
        policies=(geometry.BASELINE, geometry.RARO),
        initial_pe=(166, 833),
        seeds=(0, 1),
        base=base,
    )
    res = sweep.run_sweep(hammer, verbose=True, devices=devices)
    for r in res:
        rows += sweep.result_rows(r)
    rows += _p99_ratio_rows(res, "read_disturb_hammer")

    # bundled MSR-style trace replayed through the same runner (mixed R/W)
    msr = sweep.SweepSpec(
        scenario="msr_sample",
        n_requests=msr_requests,
        policies=(geometry.BASELINE, geometry.RARO),
        initial_pe=(500,),
        seeds=(0,),
        base=base,
    )
    res_msr = sweep.run_sweep(msr, verbose=True, devices=devices)
    for r in res_msr:
        rows += sweep.result_rows(r)
    rows += _p99_ratio_rows(res_msr, "msr_sample")

    if out_dir is not None:
        paths = sweep.write_artifacts(res + res_msr, out_dir)
        print(f"# wrote {len(paths)} BENCH_*.json artifacts to {out_dir}", flush=True)
    return rows


def sweep_fault_storm(n_requests=40_000, out_dir=None, devices=None):
    """Fault-injection section rows: the ``fault_storm`` trace swept over the
    fault axes (``configs.raro_ssd.fault_storm_sweep``), reporting tail
    latency alongside the fault counters so the recovery paths (ECC penalty,
    re-placement, bad-block retirement) show up in the harness output."""
    from repro.configs import raro_ssd
    from repro.experiments import sweep

    spec = raro_ssd.fault_storm_sweep(n_requests=n_requests)
    res = sweep.run_sweep(spec, verbose=True, devices=devices)
    rows = []
    for r in res:
        rows += sweep.result_rows(r)
    rows += _p99_ratio_rows(res, "fault_storm")

    if out_dir is not None:
        paths = sweep.write_artifacts(res, out_dir)
        print(f"# wrote {len(paths)} BENCH_*.json artifacts to {out_dir}", flush=True)
    return rows


def _endurance_frontier(results):
    """Collapse runs to one frontier point per (policy, gc_objective, pe):
    mean-over-seeds read p99 / WAF / P/E variance / projected lifetime."""
    cells = sorted({(r["run"]["policy"], r["run"]["gc_objective"],
                     r["run"]["initial_pe"]) for r in results})
    points = []
    for pol, gco, pe in cells:
        sel = [r for r in results
               if (r["run"]["policy"], r["run"]["gc_objective"],
                   r["run"]["initial_pe"]) == (pol, gco, pe)]
        points.append({
            "policy": pol,
            "gc_objective": gco,
            "initial_pe": pe,
            "read_lat_p99_us": float(np.mean([r["read_lat_p99_us"] for r in sel])),
            "waf": float(np.mean([r["waf"] for r in sel])),
            "pe_variance": float(np.mean([r["pe_variance"] for r in sel])),
            "pe_max": float(np.mean([r["pe_max"] for r in sel])),
            "lifetime_years": float(np.mean([r["lifetime_years"] for r in sel])),
            "capacity_loss_gib": float(np.mean([r["capacity_loss_gib"] for r in sel])),
        })
    return points


def sweep_endurance(n_requests=24_576, out_dir=None, devices=None):
    """Endurance section rows (DESIGN.md §2E): the
    ``configs.raro_ssd.endurance_sweep`` grid — {baseline, RARO} ×
    {min-valid, lifespan} GC × wear stages — reporting the read-p99 vs WAF
    vs projected-lifetime frontier alongside the per-run rows, plus
    headline lifespan-vs-min-valid deltas. Writes the committed
    ``BENCH_endurance.json`` (frontier + rows) when ``out_dir`` is set."""
    from repro.configs import raro_ssd
    from repro.experiments import sweep

    spec = raro_ssd.endurance_sweep(n_requests=n_requests)
    res = sweep.run_sweep(spec, verbose=True, devices=devices)
    rows = []
    for r in res:
        rows += sweep.result_rows(r, prefix="endurance")

    frontier = _endurance_frontier(res)
    for p in frontier:
        stem = (f"endurance/{p['policy']}_gc_{p['gc_objective']}"
                f"_pe{p['initial_pe']}")
        rows.append((f"{stem}/read_lat_p99_us", p["read_lat_p99_us"], "us"))
        rows.append((f"{stem}/waf", p["waf"], "ratio"))
        rows.append((f"{stem}/pe_variance", p["pe_variance"], "cycles^2"))
        rows.append((f"{stem}/lifetime_years", p["lifetime_years"], "years"))
    # headline: what the lifespan objective buys (and costs) per policy
    for pol in sorted({p["policy"] for p in frontier}):
        by_obj = {}
        for obj in ("min_valid", "lifespan"):
            v = [p for p in frontier
                 if p["policy"] == pol and p["gc_objective"] == obj]
            if v:
                by_obj[obj] = v
        if len(by_obj) == 2:
            for metric, unit in (("waf", "x"), ("pe_variance", "x"),
                                 ("lifetime_years", "x")):
                a = np.mean([p[metric] for p in by_obj["lifespan"]])
                b = np.mean([p[metric] for p in by_obj["min_valid"]])
                rows.append((f"endurance/{pol}/lifespan_vs_min_valid_{metric}",
                             float(a / max(b, 1e-12)), unit))

    if out_dir is not None:
        out = Path(out_dir)
        out.mkdir(parents=True, exist_ok=True)
        doc = {
            "bench": "endurance",
            "config": {
                "scenario": spec.scenario,
                "n_requests": spec.n_requests,
                "n_runs": spec.n_runs(),
                "policies": sorted({r["run"]["policy"] for r in res}),
                "gc_objectives": list(spec.gc_objective),
                "initial_pe": list(spec.initial_pe),
                "gc_alpha": spec.base.gc_alpha,
                "gc_beta": spec.base.gc_beta,
                "gc_gamma": spec.base.gc_gamma,
            },
            "frontier": frontier,
            "rows": [list(r) for r in rows],
        }
        p = out / "BENCH_endurance.json"
        p.write_text(json.dumps(doc, indent=1, sort_keys=True))
        print(f"# wrote {p}", flush=True)
        paths = sweep.write_artifacts(res, out_dir)
        print(f"# wrote {len(paths)} BENCH_*.json artifacts to {out_dir}", flush=True)
    return rows


def _wearout_frontier(results):
    """Collapse runs to one failure-dashboard cell per (policy,
    gc_objective, wear slope, pe): reliability counters + tail latency."""
    cells = sorted({(r["run"]["policy"], r["run"]["gc_objective"],
                     r["run"]["fault_wear_slope"], r["run"]["initial_pe"])
                    for r in results})
    points = []
    for pol, gco, slope, pe in cells:
        sel = [r for r in results
               if (r["run"]["policy"], r["run"]["gc_objective"],
                   r["run"]["fault_wear_slope"], r["run"]["initial_pe"])
               == (pol, gco, slope, pe)]
        mean = lambda k: float(np.mean([r[k] for r in sel]))  # noqa: E731
        points.append({
            "policy": pol,
            "gc_objective": gco,
            "fault_wear_slope": slope,
            "initial_pe": pe,
            "uncorrectable_reads": mean("uncorrectable_reads"),
            "rebuilds": mean("rebuilds"),
            "data_loss": mean("data_loss"),
            "bad_blocks": mean("bad_blocks"),
            "spares_remaining": mean("spares_remaining"),
            "degraded_writes": mean("degraded_writes"),
            "dropped_writes": mean("dropped_writes"),
            "read_lat_p99_us": mean("read_lat_p99_us"),
            "waf": mean("waf"),
            "pe_max": mean("pe_max"),
        })
    return points


def sweep_wearout(n_requests=24_576, out_dir=None, devices=None):
    """Wear-correlated failure section rows (DESIGN.md §2D): the
    ``configs.raro_ssd.wearout_sweep`` grid — {baseline, RARO} ×
    {min-valid, lifespan} GC × {flat, wear-correlated} rates × drive age
    with die-parity rebuild and a finite spare pool — reporting the failure
    dashboard (uncorrectables / rebuilds / data loss / spare drain /
    degraded writes) alongside tail latency, plus headline
    lifespan-vs-min-valid failure ratios at the wear-correlated points.
    Writes the committed ``BENCH_wearout.json`` when ``out_dir`` is set."""
    from repro.configs import raro_ssd
    from repro.experiments import sweep

    spec = raro_ssd.wearout_sweep(n_requests=n_requests)
    res = sweep.run_sweep(spec, verbose=True, devices=devices)
    rows = []
    for r in res:
        rows += sweep.result_rows(r, prefix="wearout")

    frontier = _wearout_frontier(res)
    for p in frontier:
        stem = (f"wearout/{p['policy']}_gc_{p['gc_objective']}"
                f"_wear{p['fault_wear_slope']:g}_pe{p['initial_pe']}")
        rows.append((f"{stem}/uncorrectable_reads",
                     p["uncorrectable_reads"], "reads"))
        rows.append((f"{stem}/rebuilds", p["rebuilds"], "rebuilds"))
        rows.append((f"{stem}/data_loss", p["data_loss"], "stripes"))
        rows.append((f"{stem}/bad_blocks", p["bad_blocks"], "blocks"))
        rows.append((f"{stem}/spares_remaining",
                     p["spares_remaining"], "blocks"))
        rows.append((f"{stem}/read_lat_p99_us", p["read_lat_p99_us"], "us"))
    # headline: what lifespan-aware GC buys on the failure trajectories at
    # the wear-correlated high-age points (the dashboard's thesis)
    slope_hi = max(p["fault_wear_slope"] for p in frontier)
    pe_hi = max(p["initial_pe"] for p in frontier)
    for pol in sorted({p["policy"] for p in frontier}):
        by_obj = {}
        for obj in ("min_valid", "lifespan"):
            v = [p for p in frontier
                 if (p["policy"], p["gc_objective"], p["fault_wear_slope"],
                     p["initial_pe"]) == (pol, obj, slope_hi, pe_hi)]
            if v:
                by_obj[obj] = v[0]
        if len(by_obj) == 2:
            for metric in ("uncorrectable_reads", "data_loss", "bad_blocks"):
                a = by_obj["lifespan"][metric]
                b = by_obj["min_valid"][metric]
                rows.append(
                    (f"wearout/{pol}/lifespan_vs_min_valid_{metric}",
                     float(a / max(b, 1e-12)), "x")
                )

    if out_dir is not None:
        out = Path(out_dir)
        out.mkdir(parents=True, exist_ok=True)
        doc = {
            "bench": "wearout",
            "config": {
                "scenario": spec.scenario,
                "n_requests": spec.n_requests,
                "n_runs": spec.n_runs(),
                "policies": sorted({r["run"]["policy"] for r in res}),
                "gc_objectives": list(spec.gc_objective),
                "initial_pe": list(spec.initial_pe),
                "fault_wear_slope": list(spec.fault_wear_slope),
                "fault_wear_power": spec.base.fault_wear_power,
                "read_fail_rate": list(spec.read_fail_rate),
                "prog_fail_rate": list(spec.prog_fail_rate),
                "erase_fail_rate": list(spec.erase_fail_rate),
                "max_read_retries": list(spec.max_read_retries),
                "spare_blocks": list(spec.spare_blocks),
                "parity_rebuild": [bool(v) for v in spec.parity_rebuild],
            },
            "frontier": frontier,
            "rows": [list(r) for r in rows],
        }
        p = out / "BENCH_wearout.json"
        p.write_text(json.dumps(doc, indent=1, sort_keys=True))
        print(f"# wrote {p}", flush=True)
        paths = sweep.write_artifacts(res, out_dir, prefix="wearout")
        print(f"# wrote {len(paths)} BENCH_*.json artifacts to {out_dir}", flush=True)
    return rows


# ------------------------- sharded scaling bench ---------------------------


def scaling_spec(n_requests: int, seeds: int, smoke: bool):
    """The grid the scaling bench times: 2 wear stages x ``seeds`` seeds per
    policy group, on the unit-test geometry when ``smoke``."""
    from repro.experiments import sweep
    from repro.ssdsim import geometry

    base = (geometry.tiny_config() if smoke
            else geometry.SimConfig(device_age_h=24.0))
    return sweep.SweepSpec(
        scenario="read_disturb_hammer",
        n_requests=n_requests,
        policies=(geometry.BASELINE, geometry.RARO),
        initial_pe=(166, 833),
        seeds=tuple(range(seeds)),
        base=base,
    )


def bench_scaling(spec, n_devices: int, repeats: int):
    """Time ``run_sweep`` end to end (dispatch + execute + batched
    device_get + host summarize) on the vmapped single-device path and
    sharded across ``n_devices``; after timing, the two paths' last result
    sets are asserted identical (the equivalence the tests guarantee,
    re-checked on the benchmark grid for free). Yields harness rows."""
    from repro.experiments import sweep

    n_runs = spec.n_runs()
    repeats = max(repeats, 1)  # the loop must bind res / divide by repeats

    def timed(devices):
        sweep.run_sweep(spec, devices=devices)  # warm-up: compile + page in
        t0 = time.perf_counter()
        for _ in range(repeats):
            res = sweep.run_sweep(spec, devices=devices)
        return (time.perf_counter() - t0) / repeats, res

    dt1, res1 = timed(None)
    dtn, resn = timed(n_devices)
    sweep.assert_results_identical(res1, resn)

    yield "sweep/scaling/n_runs", float(n_runs), "runs"
    yield "sweep/scaling/vmap1/wall_s", dt1, "s"
    yield "sweep/scaling/vmap1/runs_per_sec", n_runs / dt1, "runs/s"
    yield f"sweep/scaling/sharded{n_devices}/wall_s", dtn, "s"
    yield f"sweep/scaling/sharded{n_devices}/runs_per_sec", n_runs / dtn, "runs/s"
    yield f"sweep/scaling/sharded{n_devices}/speedup", dt1 / dtn, "x"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="unit-test geometry + small grid (CI)")
    ap.add_argument("--devices", default=None,
                    help="device count for the sharded pass, or 'all' "
                         "(default: every visible device)")
    ap.add_argument("--fake-devices", type=int, default=None, metavar="N",
                    help="set XLA_FLAGS=--xla_force_host_platform_device_count"
                         "=N before jax loads (local convenience; CI sets the "
                         "env var itself)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--seeds", type=int, default=4,
                    help="seeds per (policy, wear) cell of the timed grid")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default=".", metavar="DIR",
                    help="directory for the BENCH_sweep.json artifact")
    args = ap.parse_args()

    from repro.hostdev import fake_host_devices  # jax-free import

    fake_host_devices(args.fake_devices)

    import jax  # after the XLA_FLAGS mutation above

    from repro.experiments import sweep

    n_devices = (
        len(jax.devices()) if args.devices in (None, "all")
        else int(args.devices)
    )
    # fail fast: the sharded pass runs *after* the vmapped warm-up+timing,
    # so without this an invalid --devices only errors minutes in
    sweep.resolve_devices(n_devices)
    spec = scaling_spec(
        args.requests or (16 * 128 if args.smoke else 40_000),
        args.seeds, args.smoke,
    )

    rows = []
    print("name,value,unit")
    for row in bench_scaling(spec, n_devices, args.repeats):
        rows.append(list(row))
        n, v, u = row
        print(f"{n},{v:.4f},{u}", flush=True)

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    doc = {
        "bench": "sweep",
        "config": {
            "smoke": args.smoke,
            "scenario": spec.scenario,
            "n_requests": spec.n_requests,
            "n_runs": spec.n_runs(),
            "seeds": len(spec.seeds),
            "devices": n_devices,
            "visible_devices": len(jax.devices()),
            "platform": jax.devices()[0].platform,
            "repeats": args.repeats,
        },
        "rows": rows,
    }
    p = out / "BENCH_sweep.json"
    p.write_text(json.dumps(doc, indent=1, sort_keys=True))
    print(f"# wrote {p}")


if __name__ == "__main__":
    main()
