"""Layer-B benchmark: RARO-tiered KV cache vs static-tier baselines —
the serving analogue of the paper's IOPS-vs-capacity trade (Figs. 13/14).

Reports, per policy: KV HBM bytes (capacity), decode-output drift vs an
exact bf16 cache (the 'read reliability' axis), and the modeled per-token
HBM read traffic (the perf axis a real TPU is bound by at decode)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def kv_policy_comparison(steps=48, batch=2, seed=0):
    from repro.kvcache import tiers
    from repro.launch import serve

    rows = []
    cfg = serve.serve_cfg()
    import jax

    from repro.models import base, registry

    params = base.materialize(registry.get_api(cfg).specs(), jax.random.PRNGKey(seed),
                              jnp.float32)

    # RARO (selective thresholds so only genuinely hot pages earn bf16)
    out = serve.run(steps=steps, batch=batch, raro_enabled=True, cfg=cfg,
                    params=params, quiet=True)
    rows += [(f"tiered_kv/raro/{k}", v, "") for k, v in out.items()
             if not isinstance(v, list)]
    rows.append(("tiered_kv/raro/pages_bf16_int8_int4",
                 float("nan"), str(out["tier_pages"])))

    # static int4 (all-QLC analogue = the paper's Baseline device)
    out4 = serve.run(steps=steps, batch=batch, raro_enabled=False, cfg=cfg,
                     params=params, quiet=True)
    rows += [(f"tiered_kv/int4_only/{k}", v, "") for k, v in out4.items()
             if not isinstance(v, list)]

    # headline: quality improvement at sub-bf16 capacity
    rows.append(("tiered_kv/drift_ratio_int4_over_raro",
                 out4["mean_prob_drift"] / max(out["mean_prob_drift"], 1e-12), "x"))
    rows.append(("tiered_kv/raro_capacity_vs_bf16", 1 - out["capacity_saving"], "x"))
    return rows
