import sys
from pathlib import Path

ROOT = Path(__file__).parent
for p in (ROOT, ROOT / "src", ROOT / "tests"):
    if str(p) not in sys.path:
        sys.path.insert(0, str(p))
