"""Quickstart: the paper in 60 seconds.

Runs the FEMU-analogue flash simulator with the three schemes of §V
(Baseline / Hotness / RARO) on a Zipf-1.2 random-read workload at the
middle wear stage, and prints the paper's headline numbers: random-read
IOPS and usable-capacity loss.

  PYTHONPATH=src python examples/quickstart.py [--requests 100000]
"""

import argparse

from repro.ssdsim import engine, geometry, workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=100_000)
    ap.add_argument("--zipf", type=float, default=1.2)
    ap.add_argument("--stage", default="middle", choices=["young", "middle", "old"])
    args = ap.parse_args()

    pe = {"young": 166, "middle": 500, "old": 833}[args.stage]
    print(f"== RARO quickstart: zipf {args.zipf}, {args.stage} stage "
          f"(P/E={pe}), {args.requests} reads ==")
    results = {}
    for pol in (geometry.BASELINE, geometry.HOTNESS, geometry.RARO):
        cfg = geometry.SimConfig(policy=pol, initial_pe=pe, device_age_h=24.0)
        tr = workload.zipf_read_trace(cfg, args.requests, args.zipf, seed=1)
        s, _ = engine.run(cfg, tr)
        m = engine.summarize(s, cfg)
        results[pol] = m
        print(f"{geometry.POLICY_NAMES[pol]:>9}: IOPS={m['iops']:>9.0f}  "
              f"retries/read={m['retries_per_read']:.2f}  "
              f"capacity loss={m['capacity_loss_gib']*1024:.0f} MiB  "
              f"migrated pages={m['migrated_pages']:.0f}")

    b, h, r = (results[p] for p in (geometry.BASELINE, geometry.HOTNESS, geometry.RARO))
    print(f"\nRARO vs Baseline IOPS: {r['iops']/b['iops']:.1f}x "
          f"(paper: 9.3–14.25x)")
    save = 1 - r["capacity_loss_gib"] / max(h["capacity_loss_gib"], 1e-9)
    print(f"RARO vs Hotness capacity-loss saving: {save*100:.0f}% "
          f"(paper: 38.6–77.6%)")


if __name__ == "__main__":
    main()
