"""Serving example: batched decode with the RARO-tiered KV cache (the
paper's technique as a TPU serving feature, DESIGN.md §2B).

Decodes a batch of sequences with the Pallas tiered-attention kernel
(interpret mode on CPU), RARO promoting hot pages to bf16 and demoting
cold ones to int4, then compares against static all-int4:

  PYTHONPATH=src python examples/serve_tiered.py --steps 64 --batch 4
"""

from repro.launch.serve import main

if __name__ == "__main__":
    main()
