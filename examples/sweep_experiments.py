"""Experiments-subsystem tour: batched sweeps + tail latency in ~1 minute.

Runs a batched policy x wear x seed grid on any registered scenario
(synthetic generators or the bundled MSR-style trace replay) and prints a
tail-latency table — the metric read retries actually damage. Per-run
BENCH_*.json artifacts land in --out. --devices N shards the run axis
across devices (identical results); --fake-devices N demos it on CPU.

  PYTHONPATH=src python examples/sweep_experiments.py \\
      [--scenario read_disturb_hammer] [--requests 24000] [--out bench_out] \\
      [--devices N|all] [--fake-devices N]
  PYTHONPATH=src python examples/sweep_experiments.py --list
"""

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="read_disturb_hammer")
    ap.add_argument("--requests", type=int, default=24_000)
    ap.add_argument("--seeds", type=int, default=2)
    ap.add_argument("--devices", default=None,
                    help="shard the run axis across N devices ('all' = every "
                         "visible device; default: single-device vmap)")
    ap.add_argument("--fake-devices", type=int, default=None, metavar="N",
                    help="fake N host devices via XLA_FLAGS (set before jax "
                         "loads) to try --devices on a CPU-only box")
    ap.add_argument("--out", default=None, help="artifact directory")
    ap.add_argument("--list", action="store_true", help="list scenarios and exit")
    args = ap.parse_args()

    from repro.hostdev import fake_host_devices  # jax-free import

    fake_host_devices(args.fake_devices)

    from repro.experiments import registry, sweep
    from repro.ssdsim import geometry

    if args.list:
        print("registered scenarios:", ", ".join(registry.names()))
        return
    if args.scenario not in registry.names():
        ap.error(f"unknown scenario {args.scenario!r}; have {registry.names()}")

    spec = sweep.SweepSpec(
        scenario=args.scenario,
        n_requests=args.requests,
        policies=(geometry.BASELINE, geometry.HOTNESS, geometry.RARO),
        initial_pe=(166, 833),
        seeds=tuple(range(args.seeds)),
        base=geometry.SimConfig(device_age_h=24.0),
    )
    print(f"== sweep: {args.scenario}, {spec.n_runs()} runs "
          f"({len(spec.policies)} policies x {len(spec.initial_pe)} wear "
          f"stages x {args.seeds} seeds), one jit per policy ==")
    results = sweep.run_sweep(spec, verbose=True, devices=args.devices)

    hdr = f"{'run':<44} {'mean us':>9} {'p50 us':>9} {'p95 us':>9} {'p99 us':>9} {'p999 us':>9}"
    print(hdr)
    print("-" * len(hdr))
    for r in results:
        print(f"{r['run']['tag']:<44} {r['mean_read_latency_us']:>9.1f} "
              f"{r['read_lat_p50_us']:>9.1f} {r['read_lat_p95_us']:>9.1f} "
              f"{r['read_lat_p99_us']:>9.1f} {r['read_lat_p999_us']:>9.1f}")

    if args.out:
        paths = sweep.write_artifacts(results, args.out)
        print(f"\nwrote {len(paths)} artifacts to {args.out}/")


if __name__ == "__main__":
    main()
