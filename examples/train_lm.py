"""End-to-end training driver example: train a ~100M-param LM for a few
hundred steps on the synthetic-but-learnable stream, with checkpointing +
resume. Uses the tinyllama-1.1b family at reduced width (CPU-friendly);
pass --full on real hardware.

  PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import tempfile

from repro.launch.train import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="raro_ckpt_")
    print(f"checkpoints -> {ckpt}")
    _, hist = run(args.arch, smoke=True, steps=args.steps, batch=args.batch,
                  seq=args.seq, ckpt_dir=ckpt, ckpt_interval=100, lr=2e-3)
    print(f"loss: {hist[0][1]:.3f} -> {hist[-1][1]:.3f} "
          f"(ln(vocab) = {__import__('math').log(512):.3f})")


if __name__ == "__main__":
    main()
