"""Distributed-ready checkpointing: atomic, async, mesh-agnostic.

Arrays are gathered to host and written one file per leaf (npz) plus a
manifest; a checkpoint directory becomes visible only via atomic rename, so
a failure mid-save can never corrupt the restore path. Restore reshards
onto whatever mesh/shardings the new job provides — elastic scaling: a
checkpoint written on 2x16x16 restores onto 16x16 (or 1 CPU device)
unchanged.

Async mode offloads the host-side write to a worker thread (double-buffered
by copying to numpy first), so the train loop only blocks for the
device-to-host transfer.
"""

from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        items = tree.items()
    elif hasattr(tree, "_asdict"):
        items = tree._asdict().items()
    elif isinstance(tree, (list, tuple)):
        items = ((str(i), v) for i, v in enumerate(tree))
    else:
        return {prefix.rstrip("."): tree}
    for k, v in items:
        out.update(_flatten(v, f"{prefix}{k}."))
    return out


def save(path: str | Path, tree, *, step: int, extra: dict | None = None,
         async_: bool = False):
    """Write checkpoint at ``path`` (atomic). Returns a join() callable."""
    path = Path(path)
    tmp = path.with_name(path.name + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    manifest = {
        "step": step,
        "extra": extra or {},
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in host.items()},
    }
    # npz cannot serialize ml_dtypes (bfloat16 etc.) — store as uint16 view,
    # the manifest dtype tag restores the view on load.
    host = {
        k: (v.view(np.uint16) if v.dtype.name == "bfloat16" else v)
        for k, v in host.items()
    }

    def _write():
        np.savez(tmp / "arrays.npz", **{k: v for k, v in host.items()})
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if path.exists():
            shutil.rmtree(path)
        tmp.rename(path)

    if async_:
        th = threading.Thread(target=_write, daemon=True)
        th.start()
        return th.join
    _write()
    return lambda: None


def restore(path: str | Path, like_tree, *, shardings=None):
    """Restore into the structure of ``like_tree`` (reshards if shardings
    given). Returns (tree, manifest)."""
    path = Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    data = np.load(path / "arrays.npz")

    flat_keys = list(_flatten(like_tree).keys())
    missing = [k for k in flat_keys if k not in data.files]
    if missing:
        raise ValueError(f"checkpoint missing leaves: {missing[:5]}...")

    leaves_like, treedef = jax.tree_util.tree_flatten(like_tree)
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else [None] * len(leaves_like)
    )
    out = []
    for k, like, sh in zip(flat_keys, leaves_like, shard_leaves):
        arr = data[k]
        if manifest["leaves"][k]["dtype"] == "bfloat16":
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"{k}: shape {arr.shape} != expected {like.shape}")
        arr = arr.astype(like.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest
