"""Checkpoint rotation + failure handling for long training runs.

- keeps the newest ``keep`` checkpoints, deleting older ones only after a
  newer one is durably visible (atomic rename in checkpoint.save);
- `latest()` scans for the newest VALID checkpoint, skipping half-written
  or corrupt directories — restart-after-crash just works;
- `WatchdogState` is the deterministic failover decision logic for
  multi-host runs: hosts heartbeat, stale hosts are declared dead after
  ``timeout_s``, and the survivor set maps to a (possibly smaller) data-
  parallel width — the checkpoint being mesh-agnostic makes the elastic
  restart a pure re-layout. The transport (who pings whom) is deployment-
  specific; the DECISION logic here is what must be correct, so it is pure
  and unit-tested.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path

from repro.checkpoint import checkpoint as ckpt


class CheckpointManager:
    def __init__(self, root: str | Path, *, keep: int = 3, interval: int = 100,
                 async_: bool = True):
        self.root = Path(root)
        self.keep = keep
        self.interval = interval
        self.async_ = async_
        self._pending = None
        self.root.mkdir(parents=True, exist_ok=True)

    def dir_for(self, step: int) -> Path:
        return self.root / f"step_{step:010d}"

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.interval == 0

    def save(self, step: int, tree, extra: dict | None = None):
        if self._pending is not None:
            self._pending()  # join previous async write
        self._pending = ckpt.save(self.dir_for(step), tree, step=step,
                                  extra=extra, async_=self.async_)
        self._gc()

    def wait(self):
        if self._pending is not None:
            self._pending()
            self._pending = None

    def _valid(self, d: Path) -> bool:
        try:
            json.loads((d / "manifest.json").read_text())
            return (d / "arrays.npz").exists()
        except Exception:
            return False

    def all_steps(self) -> list[int]:
        out = []
        for d in sorted(self.root.glob("step_*")):
            if self._valid(d):
                out.append(int(d.name.split("_")[1]))
        return out

    def latest(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore_latest(self, like_tree, shardings=None):
        step = self.latest()
        if step is None:
            return None
        tree, manifest = ckpt.restore(self.dir_for(step), like_tree, shardings=shardings)
        return step, tree, manifest

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            import shutil

            shutil.rmtree(self.dir_for(s), ignore_errors=True)


# ---------------------------------------------------------------------------
# Deterministic failover decision logic
# ---------------------------------------------------------------------------
@dataclass
class WatchdogState:
    n_hosts: int
    timeout_s: float = 60.0
    last_seen: dict[int, float] = field(default_factory=dict)

    def heartbeat(self, host: int, now: float):
        self.last_seen[host] = now

    def dead_hosts(self, now: float) -> list[int]:
        return [h for h in range(self.n_hosts)
                if now - self.last_seen.get(h, -1e18) > self.timeout_s]

    def plan(self, now: float, dp_width: int) -> dict:
        """Failover plan: survivors, new DP width (largest power-of-two
        <= survivors that divides the original width's host-per-replica
        grouping), and whether a restart is required."""
        dead = self.dead_hosts(now)
        alive = self.n_hosts - len(dead)
        new_dp = dp_width
        while new_dp > 1 and new_dp > alive:
            new_dp //= 2
        return {
            "dead": dead,
            "alive": alive,
            "restart_required": bool(dead),
            "new_dp_width": max(new_dp, 1),
            "action": "elastic_restart_from_latest_checkpoint" if dead else "none",
        }
