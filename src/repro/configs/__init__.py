"""Architecture registry: --arch <id> selects one of these configs."""
from repro.configs import (
    deepseek_7b, deepseek_v3_671b, granite_moe_3b_a800m, internvl2_76b,
    qwen1_5_110b, tinyllama_1_1b, whisper_medium, xlstm_125m, yi_6b,
    zamba2_2_7b,
)
from repro.configs.base import ModelConfig, ShapeConfig, smoke_variant
from repro.configs.shapes import ALL_SHAPES, SHAPES, applicable

ARCHS = {
    m.CONFIG.arch: m.CONFIG
    for m in (
        deepseek_7b, qwen1_5_110b, yi_6b, tinyllama_1_1b, deepseek_v3_671b,
        granite_moe_3b_a800m, whisper_medium, xlstm_125m, internvl2_76b,
        zamba2_2_7b,
    )
}

__all__ = ["ARCHS", "SHAPES", "ALL_SHAPES", "ModelConfig", "ShapeConfig",
           "smoke_variant", "applicable"]
