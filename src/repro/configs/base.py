"""Model + shape configuration dataclasses (the config system)."""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str  # dense | moe | encdec | ssm | hybrid | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    first_k_dense: int = 0
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    # --- MLA (DeepSeek) ---
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0
    # --- MTP (DeepSeek-V3 multi-token prediction) ---
    mtp_depth: int = 0
    mtp_loss_coef: float = 0.3
    # --- encoder-decoder (whisper) ---
    n_enc_layers: int = 0
    enc_len: int = 1500
    # --- SSM ---
    ssm_kind: str = ""  # xlstm | mamba2
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    slstm_every: int = 0  # xlstm: every k-th block is sLSTM (rest mLSTM)
    # --- hybrid (zamba2) ---
    attn_every: int = 0  # shared attention block every k SSM layers
    window: int = 0  # sliding-window size for long-context attention
    # --- VLM stub frontend ---
    n_img_tokens: int = 0
    # --- compute / perf-iteration knobs (§Perf) ---
    dtype: Any = jnp.bfloat16
    remat: bool = True
    kv_bits: int = 16  # 16 | 8 | 4 — RARO dense-tier KV cache for decode
    xent_chunk: int = 0  # >0: chunked tied-embedding cross-entropy
    moe_hints: bool = False  # explicit dispatch sharding constraints

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_head=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        dtype=jnp.float32,
        remat=False,
    )
    if cfg.n_experts:
        kw.update(n_experts=8, top_k=2, moe_d_ff=64,
                  n_shared_experts=min(cfg.n_shared_experts, 1),
                  first_k_dense=min(cfg.first_k_dense, 1))
    if cfg.mla:
        kw.update(q_lora_rank=64, kv_lora_rank=32, rope_head_dim=16,
                  nope_head_dim=32, v_head_dim=32)
    if cfg.mtp_depth:
        kw.update(mtp_depth=1)
    if cfg.n_enc_layers:
        kw.update(n_enc_layers=2, enc_len=32)
    if cfg.ssm_kind:
        kw.update(d_state=16, d_conv=4, expand=2)
    if cfg.slstm_every:
        kw.update(slstm_every=2)
    if cfg.attn_every:
        kw.update(attn_every=2)
    if cfg.window:
        kw.update(window=64)
    if cfg.n_img_tokens:
        kw.update(n_img_tokens=16)
    return cfg.with_(**kw)
