"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437]. d_ff=2048 is the routed-expert width; the first 3
layers are dense (width 18432, per the paper)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="deepseek-v3-671b", family="moe", n_layers=61, d_model=7168, n_heads=128,
    n_kv_heads=128, d_ff=18432, vocab=129280,
    n_experts=256, top_k=8, moe_d_ff=2048, n_shared_experts=1, first_k_dense=3,
    mla=True, q_lora_rank=1536, kv_lora_rank=512, rope_head_dim=64,
    nope_head_dim=128, v_head_dim=128, mtp_depth=1,
)
