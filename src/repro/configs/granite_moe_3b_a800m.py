"""granite-moe-3b-a800m [moe] — 40 experts top-8, GQA kv=8
[hf:ibm-granite/granite-3.0-3b-a800m-base]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536, n_heads=24,
    n_kv_heads=8, d_ff=0, vocab=49155, d_head=64,
    n_experts=40, top_k=8, moe_d_ff=512,
)
