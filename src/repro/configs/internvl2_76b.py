"""internvl2-76b [vlm] — InternViT frontend STUB (precomputed patch
embeddings) + LLaMA-3-70B-style backbone [arXiv:2404.16821]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="internvl2-76b", family="vlm", n_layers=80, d_model=8192, n_heads=64,
    n_kv_heads=8, d_ff=28672, vocab=128256, n_img_tokens=256,
)
