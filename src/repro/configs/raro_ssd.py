"""The paper's own system config: FEMU-emulated hybrid SSD presets
(Table III geometry) at the three wear stages."""
from repro.ssdsim.geometry import SimConfig, RARO, HOTNESS, BASELINE

YOUNG = SimConfig(policy=RARO, initial_pe=166, device_age_h=24.0)
MIDDLE = SimConfig(policy=RARO, initial_pe=500, device_age_h=24.0)
OLD = SimConfig(policy=RARO, initial_pe=833, device_age_h=24.0)
STAGES = {"young": YOUNG, "middle": MIDDLE, "old": OLD}
