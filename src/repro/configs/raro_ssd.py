"""The paper's own system config: FEMU-emulated hybrid SSD presets
(Table III geometry) at the three wear stages."""
from repro.ssdsim.geometry import SimConfig, RARO, HOTNESS, BASELINE

YOUNG = SimConfig(policy=RARO, initial_pe=166, device_age_h=24.0)
MIDDLE = SimConfig(policy=RARO, initial_pe=500, device_age_h=24.0)
OLD = SimConfig(policy=RARO, initial_pe=833, device_age_h=24.0)
STAGES = {"young": YOUNG, "middle": MIDDLE, "old": OLD}
STAGE_PE = {"young": 166, "middle": 500, "old": 833}


def tail_latency_sweep(scenario: str = "read_disturb_hammer",
                       n_requests: int = 80_000,
                       stages=("young", "old"), seeds=(0, 1)):
    """Canonical tail-latency experiment grid (paper Figs. 13-18 axes):
    baseline-vs-RARO across wear stages and seeds, batched by the vmapped
    sweep runner (repro.experiments.sweep)."""
    from repro.experiments.sweep import SweepSpec

    return SweepSpec(
        scenario=scenario,
        n_requests=n_requests,
        policies=(BASELINE, RARO),
        initial_pe=tuple(STAGE_PE[s] for s in stages),
        seeds=tuple(seeds),
        base=SimConfig(device_age_h=24.0),
    )
