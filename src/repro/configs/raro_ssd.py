"""The paper's own system config: FEMU-emulated hybrid SSD presets
(Table III geometry) at the three wear stages."""
from repro.ssdsim.geometry import SimConfig, RARO, HOTNESS, BASELINE

YOUNG = SimConfig(policy=RARO, initial_pe=166, device_age_h=24.0)
MIDDLE = SimConfig(policy=RARO, initial_pe=500, device_age_h=24.0)
OLD = SimConfig(policy=RARO, initial_pe=833, device_age_h=24.0)
STAGES = {"young": YOUNG, "middle": MIDDLE, "old": OLD}
STAGE_PE = {"young": 166, "middle": 500, "old": 833}


def tail_latency_sweep(scenario: str = "read_disturb_hammer",
                       n_requests: int = 80_000,
                       stages=("young", "old"), seeds=(0, 1)):
    """Canonical tail-latency experiment grid (paper Figs. 13-18 axes):
    baseline-vs-RARO across wear stages and seeds, batched by the vmapped
    sweep runner (repro.experiments.sweep)."""
    from repro.experiments.sweep import SweepSpec

    return SweepSpec(
        scenario=scenario,
        n_requests=n_requests,
        policies=(BASELINE, RARO),
        initial_pe=tuple(STAGE_PE[s] for s in stages),
        seeds=tuple(seeds),
        base=SimConfig(device_age_h=24.0),
    )


def sharded_sweep(scenario: str = "read_disturb_hammer",
                  n_requests: int = 80_000,
                  stages=("young", "middle", "old"), seeds=(0, 1, 2, 3)):
    """Device-sharded experiment grid: 3 wear stages x 4 seeds = 12 runs per
    policy group, sized so the run axis divides evenly across 2/3/4/6/12
    devices (uneven counts still work — the runner pads). Execute with
    ``run_sweep(spec, devices=N)``; on a CPU-only host fake the devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``."""
    from repro.experiments.sweep import SweepSpec

    return SweepSpec(
        scenario=scenario,
        n_requests=n_requests,
        policies=(BASELINE, RARO),
        initial_pe=tuple(STAGE_PE[s] for s in stages),
        seeds=tuple(seeds),
        base=SimConfig(device_age_h=24.0),
    )


def fault_storm_sweep(scenario: str = "fault_storm",
                      n_requests: int = 40_000,
                      prog_fail_rate=(0.0, 0.005),
                      erase_fail_rate=(0.02,),
                      max_read_retries: int = 10,
                      stage: str = "old", seeds=(0,)):
    """Failure-mode experiment grid (DESIGN.md §2D): the write-heavy
    ``fault_storm`` trace on a worn device, swept over program-failure rates
    with erase failures retiring blocks and a finite read-retry budget, so
    baseline-vs-RARO is compared under uncorrectable reads, bad-block
    retirement pressure and the re-placement/stall recovery paths. The
    fault-free point (rate 0.0) rides in the same compiled batch and stays
    bit-identical to a fault-free run."""
    from repro.experiments.sweep import SweepSpec

    return SweepSpec(
        scenario=scenario,
        n_requests=n_requests,
        policies=(BASELINE, RARO),
        initial_pe=(STAGE_PE[stage],),
        seeds=tuple(seeds),
        prog_fail_rate=tuple(prog_fail_rate),
        erase_fail_rate=tuple(erase_fail_rate),
        max_read_retries=(max_read_retries,),
        base=SimConfig(device_age_h=24.0),
    )


def endurance_sweep(scenario: str = "fault_storm",
                    n_requests: int = 24_576,
                    stages=("young", "old"), seeds=(0,),
                    gc_objectives=("min_valid", "lifespan")):
    """Multi-objective endurance grid (DESIGN.md §2E): {baseline, RARO} ×
    {min-valid GC, lifespan-aware GC} × wear stages on a write-heavy trace
    over a small high-occupancy geometry, so GC fires constantly and the
    WAF / P/E-variance / lifetime rows actually discriminate. This is the
    read-p99 vs WAF vs projected-lifetime frontier RARO claims to win —
    "did the extra conversions pay for themselves?" — rendered by
    ``benchmarks/report.py`` from ``BENCH_endurance.json``. The
    ``gc_objective`` axis batches through the traced RunKnobs code, so both
    objectives share one compiled program per policy."""
    from repro.experiments.sweep import SweepSpec

    return SweepSpec(
        scenario=scenario,
        n_requests=n_requests,
        policies=(BASELINE, RARO),
        initial_pe=tuple(STAGE_PE[s] for s in stages),
        seeds=tuple(seeds),
        gc_objective=tuple(gc_objectives),
        base=SimConfig(
            blocks_per_plane=64, slots_per_block=256, n_logical=57_344,
            chunk=256, migrate_pages_per_chunk=64,
            max_conversions_per_chunk=4, gc_free_threshold=24,
            gc_victims_per_pass=8, device_age_h=24.0,
        ),
    )


def wearout_sweep(scenario: str = "fault_storm",
                  n_requests: int = 24_576,
                  stages=("young", "old"), seeds=(0,),
                  fault_wear_slope=(0.0, 8.0),
                  gc_objectives=("min_valid", "lifespan"),
                  spare_blocks: int = 12):
    """Wear-correlated failure frontier (DESIGN.md §2D, wear-correlated):
    {baseline, RARO} × {min-valid, lifespan GC} × {flat, wear-correlated
    rates} × drive age on the write-heavy endurance geometry, with
    die-parity rebuild recovery armed and a finite over-provisioning spare
    pool, so every reliability mechanism of the model is exercised at once:
    erase failures retire blocks and drain spares, uncorrectable reads
    trigger stripe rebuilds (second faults count as data loss), and pool
    exhaustion flips the drive read-only. The flat-rate points
    (``fault_wear_slope = 0``) ride the same compiled batch and pin the
    PR-7 behavior; the wear-correlated points show failure trajectories
    bending up with age — where lifespan-aware GC's flatter worst-block
    wear should visibly buy fewer uncorrectables and data-loss events than
    min-valid on the old device. Rendered as the failure dashboard in
    ``benchmarks/report.py`` from ``BENCH_wearout.json``."""
    from repro.experiments.sweep import SweepSpec

    return SweepSpec(
        scenario=scenario,
        n_requests=n_requests,
        policies=(BASELINE, RARO),
        initial_pe=tuple(STAGE_PE[s] for s in stages),
        seeds=tuple(seeds),
        prog_fail_rate=(0.002,),
        erase_fail_rate=(0.02,),
        max_read_retries=(8,),
        read_fail_rate=(0.0005,),
        fault_wear_slope=tuple(fault_wear_slope),
        parity_rebuild=(True,),
        spare_blocks=(spare_blocks,),
        gc_objective=tuple(gc_objectives),
        base=SimConfig(
            blocks_per_plane=64, slots_per_block=256, n_logical=57_344,
            chunk=256, migrate_pages_per_chunk=64,
            max_conversions_per_chunk=4, gc_free_threshold=24,
            gc_victims_per_pass=8, device_age_h=24.0,
        ),
    )


def latency_load_sweep(scenario: str = "hammer_openloop",
                       n_requests: int = 80_000,
                       rate_iops: float = 50_000.0,
                       arrival_scale=(0.25, 0.5, 1.0, 2.0, 4.0),
                       stage: str = "old", seeds=(0,)):
    """Latency-vs-offered-load experiment grid: one open-loop retry-heavy
    trace at a base Poisson ``rate_iops``, swept over offered-load
    multipliers through the traced ``RunKnobs.arrival_scale`` knob, so the
    whole hockey-stick curve (per policy) runs as one compiled batch."""
    from repro.experiments.sweep import SweepSpec

    return SweepSpec(
        scenario=scenario,
        n_requests=n_requests,
        policies=(BASELINE, RARO),
        initial_pe=(STAGE_PE[stage],),
        seeds=tuple(seeds),
        arrival_scale=tuple(arrival_scale),
        scenario_kw=(("rate_iops", rate_iops),),
        base=SimConfig(device_age_h=24.0),
    )
