"""Assigned input-shape set (one per cell of the dry-run matrix)."""

from __future__ import annotations

from repro.configs.base import ShapeConfig

TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}

# long_500k requires sub-quadratic sequence mixing: only SSM/hybrid archs run
# it (DESIGN.md §5); pure full-attention archs skip with this rationale.
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def applicable(arch_family: str, shape: ShapeConfig) -> tuple[bool, str]:
    if shape.name == "long_500k" and arch_family not in SUBQUADRATIC_FAMILIES:
        return False, "long_500k needs sub-quadratic attention; arch is pure full-attention"
    return True, ""
