"""whisper-medium [audio] — enc-dec, conv frontend STUB (input_specs supplies
precomputed frame embeddings) [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="whisper-medium", family="encdec", n_layers=24, d_model=1024, n_heads=16,
    n_kv_heads=16, d_ff=4096, vocab=51865, n_enc_layers=24, enc_len=1500,
    norm="layernorm", act="gelu",
)
