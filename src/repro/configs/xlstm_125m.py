"""xlstm-125m [ssm] — sLSTM + mLSTM blocks (every 4th sLSTM)
[arXiv:2405.04517]. Attention-free: RARO KV tiering inapplicable."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="xlstm-125m", family="ssm", n_layers=12, d_model=768, n_heads=4,
    n_kv_heads=4, d_ff=0, vocab=50304, ssm_kind="xlstm", slstm_every=4, expand=2,
)
