"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention block every 9
layers, ssm_state=64 [arXiv:2411.15242]. Sliding-window (4096) attention
keeps long_500k sub-quadratic."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560, n_heads=32,
    n_kv_heads=32, d_ff=10240, vocab=32000, d_state=64, attn_every=9,
    window=4096, expand=2,
)
