# The paper's primary contribution: the RARO reliability-aware
# conversion/migration policy, as pure-JAX modules shared by the
# flash-simulator layer (repro.ssdsim) and the TPU KV-cache tier
# manager (repro.kvcache). See DESIGN.md §2.
from repro.core import modes  # noqa: F401  (import order: no cycles)

__all__ = ["modes", "rber", "retry", "hotness", "policy", "controller", "reclaim"]
