"""Flash-mode translation controller (paper §IV-A/§IV-D).

The per-*page* Table-II decisions are aggregated to per-*block* conversion
plans, because "the migration operation follows the principle of flash type
alignment, i.e. taking the block as the smallest management unit to guarantee
that all pages within the block remain uniform".

A block converts to the lowest-density (fastest) target requested by any of
its triggering pages; untouched blocks keep their mode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hotness, modes, policy, retry


def block_conversion_plan(page_target, page_mode, page_block, page_valid, n_blocks,
                          block_mode):
    """Aggregate page-level targets into a per-block conversion plan.

    Args:
      page_target: (P,) int32 target mode per page (== page_mode if no trigger).
      page_mode:   (P,) int32 current mode per page.
      page_block:  (P,) int32 owning physical block of each page.
      page_valid:  (P,) bool  page holds live data.
      n_blocks:    static int.
      block_mode:  (B,) int32 current block modes.

    Returns:
      (B,) int32 target block modes (= block_mode where nothing triggers).
    """
    triggered = (page_target != page_mode) & page_valid
    # min over triggering pages per block; N_MODES (out of range) = no trigger.
    req = jnp.where(triggered, page_target, modes.N_MODES)
    per_block = jax.ops.segment_min(req, page_block, num_segments=n_blocks)
    return jnp.where(per_block < modes.N_MODES, per_block, block_mode).astype(jnp.int32)


def raro_page_decision(page_mode, page_heat, page_pe_cycles, page_time_h, page_reads,
                       page_ids, heat_cfg: hotness.HeatConfig, r1: int = policy.DEFAULT_R1):
    """Full RARO per-page pipeline (paper Fig. 11 three-stage pipeline):

      1. heat classifier  ->  cold/warm/hot
      2. RBER computing + read-retry calculator (Eq. 1 -> Eq. 3)
      3. Table-II migration decision with stage-adaptive thresholds
    """
    heat_cls = hotness.classify(page_heat, heat_cfg)
    retries = retry.page_retries(page_mode, page_pe_cycles, page_time_h, page_reads, page_ids)
    th = policy.stage_thresholds(page_pe_cycles, r1=r1)
    return policy.migration_decision(page_mode, heat_cls, retries, th), retries, heat_cls


def hotness_page_decision(page_mode, page_heat, heat_cfg: hotness.HeatConfig):
    """'Hotness' comparison scheme: temperature-only decision."""
    heat_cls = hotness.classify(page_heat, heat_cfg)
    return policy.hotness_only_decision(page_mode, heat_cls), heat_cls
