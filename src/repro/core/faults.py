"""Deterministic fault-injection model (DESIGN.md §2D).

The dominant NAND field-failure modes firmware must survive (Cai et al.'s
error-characterization survey, PAPERS.md) are injected as device-level
fault classes, all jit/vmap/shard_map-safe with static shapes:

  uncorrectable reads — a read whose Eq.-3 retry count exceeds the device
      retry budget (``max_read_retries``) does not decode on-chip; on top
      of that, every read draws a wear-scaled Bernoulli uncorrectable with
      probability ``read_fail_rate`` (the probabilistic tail Cai et al.
      attribute to retention/ read-disturb excursions). Recovery is either
      a flat ECC soft-decode penalty (``read_recovery_us``) or, when
      ``parity_rebuild`` is armed, a die-parity stripe rebuild (below);
      either way the read completes and is counted in
      ``SSDState.n_uncorrectable``.
  program failures — each user-path page program fails with probability
      ``prog_fail_rate``; the failed slot is wasted (programmed but invalid)
      and the page is re-placed through the shared ``ftl._place_pages``
      machinery onto a fresh open block.
  erase failures — each block erase fails with probability
      ``erase_fail_rate``; the block is retired into the bad-block map
      (``SSDState.block_bad``, state ``BAD``), never allocated again, and
      charged against the over-provisioning spare pool
      (``SSDState.spare_count``).

**Wear-correlated rates.** Each class's base rate is scaled per-operation by
:func:`wear_mult` — ``1 + slope * (pe / rated)^power`` — evaluated from the
per-block P/E count threaded into every draw, so a worn block fails more
often than a fresh one (the nonlinear wear→error coupling of Cai et al. and
the ``rber.py`` wear-stage philosophy, continuous instead of banded). A
``wear_slope`` of exactly 0.0 multiplies every rate by exactly 1.0, which is
bit-exact in float32 — the flat-rate (PR 7) engine is the zero-slope point
of the same compiled program.

**Die-parity rebuild.** With ``parity_rebuild`` armed, an uncorrectable read
is recovered by reconstructing the page from its die-parity stripe: one
sense on every peer die plus their page transfers serialized on the channel
bus (:func:`recovery_us` gives the victim lane's added service time; the
engine additionally charges the peer dies/channels on the timing lattice).
A second uncorrectable among the peer reads during the rebuild means the
stripe cannot be reconstructed — :func:`rebuild_second_fault` draws that
event (probability ``1 - (1 - q)^n_peers`` with ``q`` the wear-scaled
``read_fail_rate``) and the engine counts it as true data loss
(``n_data_loss``). The sim keeps serving the stale page; no mapping entry
is harmed.

Randomness is a stateless counter-style hash (same construction as
``rber.page_variation``) keyed on *what* is failing and the block's P/E
cycle at the time, so a given run is bit-reproducible under jit/vmap and a
fault schedule is a pure function of ``(seed, state trajectory)`` — no PRNG
key threading through the scan.

Two activation paths share the model:

  static  — nonzero ``SimConfig`` fault knobs (``cfg.faults_enabled``); the
      constants are baked into the compiled program.
  traced  — ``RunKnobs`` fault fields (the sweep runner's fault-rate axis);
      a whole grid of fault rates shares one compiled program, and a traced
      rate of exactly zero reproduces the fault-free engine output bit for
      bit (pinned by ``tests/test_faults.py`` / ``tests/test_wearout.py``).

``params_for`` resolves the two into one :class:`FaultParams` bundle (or
``None`` when fault injection is statically off, in which case no fault ops
are traced at all — the pre-change program).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import modes


class FaultParams(NamedTuple):
    """Resolved fault knobs for one run (scalars, possibly traced).

    ``max_read_retries < 0`` disables the retry-budget uncorrectable path
    for the run even when program/erase faults are active; rates of 0.0
    never draw a failure. ``read_recovery_us`` and ``wear_power`` are
    always static (from ``SimConfig``).
    """

    max_read_retries: jnp.ndarray  # i32; < 0 = budget path off
    prog_fail_rate: jnp.ndarray  # f32 probability per page program
    erase_fail_rate: jnp.ndarray  # f32 probability per block erase
    read_fail_rate: jnp.ndarray  # f32 probability per page read
    wear_slope: jnp.ndarray  # f32 wear-curve gain; 0.0 = flat (PR 7) rates
    parity_rebuild: jnp.ndarray  # i32 0/1; 1 = die-parity rebuild recovery
    seed: jnp.ndarray  # i32 run-level stream selector
    read_recovery_us: float  # static flat ECC soft-decode penalty
    wear_power: float  # static wear-curve knee exponent


def _opt(value, default, dtype):
    """Knob field, falling back to the static config value when unset."""
    return jnp.asarray(default if value is None else value, dtype)


def params_for(cfg, knobs=None) -> FaultParams | None:
    """Resolve ``SimConfig`` + optional ``RunKnobs`` into fault parameters.

    Returns ``None`` when fault injection is statically off — neither the
    config nor the knobs carry fault fields — so callers can gate the fault
    ops out of the trace entirely (the bit-identical no-fault path).
    """
    has_knob_faults = knobs is not None and knobs.prog_fail_rate is not None
    if not (cfg.faults_enabled or has_knob_faults):
        return None
    if has_knob_faults:
        return FaultParams(
            max_read_retries=jnp.asarray(knobs.max_read_retries, jnp.int32),
            prog_fail_rate=jnp.asarray(knobs.prog_fail_rate, jnp.float32),
            erase_fail_rate=jnp.asarray(knobs.erase_fail_rate, jnp.float32),
            read_fail_rate=_opt(knobs.read_fail_rate,
                                cfg.read_fail_rate, jnp.float32),
            wear_slope=_opt(knobs.fault_wear_slope,
                            cfg.fault_wear_slope, jnp.float32),
            parity_rebuild=_opt(knobs.parity_rebuild,
                                cfg.parity_rebuild, jnp.int32),
            seed=jnp.asarray(knobs.fault_seed, jnp.int32),
            read_recovery_us=cfg.read_recovery_us,
            wear_power=cfg.fault_wear_power,
        )
    return FaultParams(
        max_read_retries=jnp.int32(cfg.max_read_retries),
        prog_fail_rate=jnp.float32(cfg.prog_fail_rate),
        erase_fail_rate=jnp.float32(cfg.erase_fail_rate),
        read_fail_rate=jnp.float32(cfg.read_fail_rate),
        wear_slope=jnp.float32(cfg.fault_wear_slope),
        parity_rebuild=jnp.int32(int(cfg.parity_rebuild)),
        seed=jnp.int32(cfg.fault_seed),
        read_recovery_us=cfg.read_recovery_us,
        wear_power=cfg.fault_wear_power,
    )


# draw-stream selectors: the fault classes must never share a draw even when
# keyed on the same (id, pe) pair
STREAM_PROG = jnp.uint32(0x50524F47)  # "PROG"
STREAM_ERASE = jnp.uint32(0x45525345)  # "ERSE"
STREAM_READ = jnp.uint32(0x52454144)  # "READ"
STREAM_REBUILD = jnp.uint32(0x52424C44)  # "RBLD"


def _mix(h):
    """One finalization round of the repo's xorshift-multiply hash."""
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def uniform01(ident, cycle, seed, stream):
    """Stateless uniform (0, 1) draw keyed on (id, P/E cycle, seed, stream).

    ``ident`` is the failing entity (slot for programs/reads, block for
    erases) and ``cycle`` its block's P/E count at the time, so re-using a
    block after an erase draws fresh outcomes — a schedule, not a fixed
    per-block fate. Same hash family as ``rber.page_variation``;
    deterministic under jit/vmap and identical across devices.
    """
    h = jnp.asarray(ident, jnp.uint32) * jnp.uint32(0x9E3779B9)
    h = _mix(h ^ (jnp.asarray(cycle, jnp.uint32) * jnp.uint32(0x68E31DA4)))
    h = _mix(h ^ (jnp.asarray(seed, jnp.uint32) * jnp.uint32(0xB5297A4D)) ^ stream)
    return (jnp.float32(h & jnp.uint32(0xFFFFFF)) + 0.5) / jnp.float32(1 << 24)


def block_entity(block, n_dies: int, planes: int):
    """Erase-fault entity of a block, keyed on its physical lattice
    coordinates ``(die, plane, index-within-plane)`` rather than the raw
    block id, so fault schedules are a property of the physical cell being
    erased and survive renumberings that keep the lattice. Plain-int
    arithmetic on purpose (no geometry import — core stays below ssdsim).

    Under the die-first striped layout (``die = block % n_dies``, ``plane =
    (block // n_dies) % planes``) the coordinates pack back to exactly the
    raw block id — ``(idx * planes + plane) * n_dies + die == block`` — so
    every existing draw is unchanged (pinned by ``tests/test_channel_model``).
    """
    die = block % n_dies
    plane = (block // n_dies) % planes
    idx = block // (n_dies * planes)
    return (idx * planes + plane) * n_dies + die


def wear_mult(p: FaultParams, pe, rated):
    """Wear-curve rate multiplier ``1 + slope * (pe / rated)^power``.

    ``rated`` is the rated endurance of the failing block's *current* mode
    (``modes.PE_LIMIT[mode]``): a QLC block at pe=900 sits at 90% of rated
    wear while an SLC block at the same count has barely aged. The power
    knee (static ``wear_power``, default 4) keeps young blocks near the
    base rate and bends failure probability up super-linearly toward
    end-of-life, matching Cai et al.'s P/E-vs-RBER curves. A slope of
    exactly 0.0 yields exactly 1.0 — multiplying any float32 rate by it is
    a bit-exact no-op, which is what pins the flat-rate engine.
    """
    frac = jnp.asarray(pe, jnp.float32) / jnp.asarray(rated, jnp.float32)
    frac = jnp.maximum(frac, 0.0)
    return 1.0 + p.wear_slope * jnp.power(frac, jnp.float32(p.wear_power))


def prog_fails(p: FaultParams, slots, pe, rated):
    """Per-lane program-failure draw for slots about to be programmed."""
    rate = p.prog_fail_rate * wear_mult(p, pe, rated)
    return uniform01(slots, pe, p.seed, STREAM_PROG) < rate


def erase_fails(p: FaultParams, blocks, pe, rated):
    """Per-lane erase-failure draw for blocks about to be erased."""
    rate = p.erase_fail_rate * wear_mult(p, pe, rated)
    return uniform01(blocks, pe, p.seed, STREAM_ERASE) < rate


def read_fails(p: FaultParams, slots, pe, rated):
    """Per-lane probabilistic-uncorrectable draw for slots being read."""
    rate = p.read_fail_rate * wear_mult(p, pe, rated)
    return uniform01(slots, pe, p.seed, STREAM_READ) < rate


def rebuild_second_fault(p: FaultParams, slots, pe, rated, n_peers: int):
    """Second-uncorrectable-during-rebuild draw (true data loss).

    A die-parity rebuild reads ``n_peers`` stripe peers; if any of those
    reads is itself uncorrectable the stripe cannot be reconstructed. Each
    peer fails with the same wear-scaled probabilistic-uncorrectable rate
    ``q`` as any read (the victim's own P/E count stands in for the
    stripe's wear — peers erase in near-lockstep under striped
    allocation), so the stripe is lost with ``1 - (1 - q)^n_peers``. One
    draw per victim lane on a dedicated stream; at ``read_fail_rate == 0``
    the loss probability is exactly 0 and the draw can never fire.
    """
    q = jnp.clip(p.read_fail_rate * wear_mult(p, pe, rated), 0.0, 1.0)
    loss_p = 1.0 - jnp.power(1.0 - q, jnp.float32(n_peers))
    return uniform01(slots, pe, p.seed, STREAM_REBUILD) < loss_p


def recovery_us(p: FaultParams, mode, cfg):
    """Victim-lane recovery time of one uncorrectable read, microseconds.

    Flat path: the static ECC soft-decode constant (PR 7). Parity path: the
    rebuild critical path as seen by the victim read — the peer senses
    overlap across dies (one read latency at the victim's mode; stripe
    peers are modeled at the same mode), then every peer page crosses a
    channel bus, of which ``cfg.rebuild_xfer_chain`` serialize behind each
    other on the busiest bus. The peer dies'/channels' own busy time is
    charged separately on the timing lattice by the engine. A one-die
    geometry has no stripe peers, so parity rebuild degenerates to the
    flat constant there.
    """
    flat = jnp.float32(p.read_recovery_us)
    if cfg.n_dies < 2:
        return jnp.broadcast_to(flat, jnp.shape(mode))
    rebuild = (modes.READ_LATENCY_US[mode]
               + jnp.float32(cfg.rebuild_xfer_chain * cfg.transfer_us))
    return jnp.where(p.parity_rebuild > 0, rebuild, flat)
