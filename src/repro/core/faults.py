"""Deterministic fault-injection model (DESIGN.md §2D).

The dominant NAND field-failure modes firmware must survive (Cai et al.'s
error-characterization survey, PAPERS.md) are injected as three device-level
fault classes, all jit/vmap/shard_map-safe with static shapes:

  uncorrectable reads — a read whose Eq.-3 retry count exceeds the device
      retry budget (``max_read_retries``) does not decode on-chip: the
      controller burns the full retry budget, then pays an ECC
      soft-decode/recovery penalty (``read_recovery_us``) and the read is
      counted in ``SSDState.n_uncorrectable``.
  program failures — each user-path page program fails with probability
      ``prog_fail_rate``; the failed slot is wasted (programmed but invalid)
      and the page is re-placed through the shared ``ftl._place_pages``
      machinery onto a fresh open block.
  erase failures — each block erase fails with probability
      ``erase_fail_rate``; the block is retired into the bad-block map
      (``SSDState.block_bad``, state ``BAD``) and never allocated again.

Randomness is a stateless counter-style hash (same construction as
``rber.page_variation``) keyed on *what* is failing and the block's P/E
cycle at the time, so a given run is bit-reproducible under jit/vmap and a
fault schedule is a pure function of ``(seed, state trajectory)`` — no PRNG
key threading through the scan.

Two activation paths share the model:

  static  — nonzero ``SimConfig`` fault knobs (``cfg.faults_enabled``); the
      constants are baked into the compiled program.
  traced  — ``RunKnobs`` fault fields (the sweep runner's fault-rate axis);
      a whole grid of fault rates shares one compiled program, and a traced
      rate of exactly zero reproduces the fault-free engine output bit for
      bit (pinned by ``tests/test_faults.py``).

``params_for`` resolves the two into one :class:`FaultParams` bundle (or
``None`` when fault injection is statically off, in which case no fault ops
are traced at all — the pre-change program).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class FaultParams(NamedTuple):
    """Resolved fault knobs for one run (scalars, possibly traced).

    ``max_read_retries < 0`` disables the uncorrectable-read path for the
    run even when program/erase faults are active; rates of 0.0 never draw
    a failure. ``read_recovery_us`` is always static (from ``SimConfig``).
    """

    max_read_retries: jnp.ndarray  # i32; < 0 = reads always decode
    prog_fail_rate: jnp.ndarray  # f32 probability per page program
    erase_fail_rate: jnp.ndarray  # f32 probability per block erase
    seed: jnp.ndarray  # i32 run-level stream selector
    read_recovery_us: float  # static ECC soft-decode/recovery penalty


def params_for(cfg, knobs=None) -> FaultParams | None:
    """Resolve ``SimConfig`` + optional ``RunKnobs`` into fault parameters.

    Returns ``None`` when fault injection is statically off — neither the
    config nor the knobs carry fault fields — so callers can gate the fault
    ops out of the trace entirely (the bit-identical no-fault path).
    """
    has_knob_faults = knobs is not None and knobs.prog_fail_rate is not None
    if not (cfg.faults_enabled or has_knob_faults):
        return None
    if has_knob_faults:
        return FaultParams(
            max_read_retries=jnp.asarray(knobs.max_read_retries, jnp.int32),
            prog_fail_rate=jnp.asarray(knobs.prog_fail_rate, jnp.float32),
            erase_fail_rate=jnp.asarray(knobs.erase_fail_rate, jnp.float32),
            seed=jnp.asarray(knobs.fault_seed, jnp.int32),
            read_recovery_us=cfg.read_recovery_us,
        )
    return FaultParams(
        max_read_retries=jnp.int32(cfg.max_read_retries),
        prog_fail_rate=jnp.float32(cfg.prog_fail_rate),
        erase_fail_rate=jnp.float32(cfg.erase_fail_rate),
        seed=jnp.int32(cfg.fault_seed),
        read_recovery_us=cfg.read_recovery_us,
    )


# draw-stream selectors: program and erase failures must never share a draw
# even when keyed on the same (id, pe) pair
STREAM_PROG = jnp.uint32(0x50524F47)  # "PROG"
STREAM_ERASE = jnp.uint32(0x45525345)  # "ERSE"


def _mix(h):
    """One finalization round of the repo's xorshift-multiply hash."""
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def uniform01(ident, cycle, seed, stream):
    """Stateless uniform (0, 1) draw keyed on (id, P/E cycle, seed, stream).

    ``ident`` is the failing entity (slot for programs, block for erases)
    and ``cycle`` its block's P/E count at the time, so re-using a block
    after an erase draws fresh outcomes — a schedule, not a fixed per-block
    fate. Same hash family as ``rber.page_variation``; deterministic under
    jit/vmap and identical across devices.
    """
    h = jnp.asarray(ident, jnp.uint32) * jnp.uint32(0x9E3779B9)
    h = _mix(h ^ (jnp.asarray(cycle, jnp.uint32) * jnp.uint32(0x68E31DA4)))
    h = _mix(h ^ (jnp.asarray(seed, jnp.uint32) * jnp.uint32(0xB5297A4D)) ^ stream)
    return (jnp.float32(h & jnp.uint32(0xFFFFFF)) + 0.5) / jnp.float32(1 << 24)


def block_entity(block, n_dies: int, planes: int):
    """Erase-fault entity of a block, keyed on its physical lattice
    coordinates ``(die, plane, index-within-plane)`` rather than the raw
    block id, so fault schedules are a property of the physical cell being
    erased and survive renumberings that keep the lattice. Plain-int
    arithmetic on purpose (no geometry import — core stays below ssdsim).

    Under the die-first striped layout (``die = block % n_dies``, ``plane =
    (block // n_dies) % planes``) the coordinates pack back to exactly the
    raw block id — ``(idx * planes + plane) * n_dies + die == block`` — so
    every existing draw is unchanged (pinned by ``tests/test_channel_model``).
    """
    die = block % n_dies
    plane = (block // n_dies) % planes
    idx = block // (n_dies * planes)
    return (idx * planes + plane) * n_dies + die


def prog_fails(p: FaultParams, slots, pe):
    """Per-lane program-failure draw for slots about to be programmed."""
    return uniform01(slots, pe, p.seed, STREAM_PROG) < p.prog_fail_rate


def erase_fails(p: FaultParams, blocks, pe):
    """Per-lane erase-failure draw for blocks about to be erased."""
    return uniform01(blocks, pe, p.seed, STREAM_ERASE) < p.erase_fail_rate
