"""Heat classifier (paper §IV-A) — exponential-decay access-frequency counters.

Works on whole arrays of counters so it can run inside jit/scan for both the
SSD simulator (per logical page) and the KV-cache tier manager (per KV page,
where "accesses" are attention-mass increments rather than unit counts).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import modes


class HeatConfig(NamedTuple):
    """Decay + classification thresholds.

    ``decay`` is applied once per *epoch* (request chunk / decode step);
    a counter that stops being touched decays to COLD within
    ``log(warm_thresh) / -log(decay)`` epochs.
    """

    decay: float = 0.95
    hot_thresh: float = 2.0
    warm_thresh: float = 0.5


def decay_heat(heat, cfg: HeatConfig):
    return heat * cfg.decay


def accumulate(heat, idx, amount=1.0):
    """Scatter-add ``amount`` at ``idx`` (duplicate indices accumulate)."""
    return heat.at[idx].add(amount)


def update_heat(heat, idx, cfg: HeatConfig, amount=1.0):
    """One epoch: decay everything, then credit the accessed entries."""
    return accumulate(decay_heat(heat, cfg), idx, amount)


def classify(heat, cfg: HeatConfig):
    """Counter values -> {COLD, WARM, HOT} labels."""
    heat = jnp.asarray(heat)
    return jnp.where(
        heat >= cfg.hot_thresh,
        modes.HOT,
        jnp.where(heat >= cfg.warm_thresh, modes.WARM, modes.COLD),
    ).astype(jnp.int32)
