"""Flash-mode / KV-tier constants shared by both layers of the framework.

Layer A (ssdsim): SLC / TLC / QLC flash modes, Table III/IV of the paper.
Layer B (kvcache): bf16 / int8 / int4 KV-page tiers — same ordering, so the
policy code in :mod:`repro.core.policy` is tier-agnostic (mode id 0 is always
the fastest/most-reliable, mode id 2 the densest).
"""

from __future__ import annotations

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Mode ids. Order matters: lower id == lower density == higher reliability.
# ---------------------------------------------------------------------------
SLC = 0
TLC = 1
QLC = 2
N_MODES = 3

MODE_NAMES = ("SLC", "TLC", "QLC")

# Bits per cell (paper §II-B). Layer B reads this as bits per KV element
# (bf16 = 16, int8 = 8, int4 = 4) via TIER_BITS below.
BITS_PER_CELL = jnp.array([1, 3, 4], dtype=jnp.int32)

# Number of reference-voltage senses for a worst-case page read (paper §II-D:
# SLC needs 1; TLC 2-3-2 Gray worst page 3, we use the commonly-cited 4 for a
# full-page LSB+CSB+MSB read; QLC needs up to 8 depending on the Gray code).
N_SENSE = jnp.array([1, 4, 8], dtype=jnp.int32)

# Device retry-table limits (a real controller has a finite retry table; the
# paper observes up to 16 on old QLC).
MAX_RETRIES = jnp.array([8, 16, 16], dtype=jnp.int32)

# Pages per block when a physical block is programmed in each mode (Table III).
PAGES_PER_BLOCK = jnp.array([256, 768, 1024], dtype=jnp.int32)

# Table IV latencies, microseconds.
READ_LATENCY_US = jnp.array([20.0, 66.0, 140.0], dtype=jnp.float32)
WRITE_LATENCY_US = jnp.array([160.0, 730.0, 3102.0], dtype=jnp.float32)
ERASE_LATENCY_US = jnp.array([2000.0, 3000.0, 10000.0], dtype=jnp.float32)

# Rated P/E endurance per mode (Table IV). RATED_PE is the host-side view
# (plain ints) so summarize/report code can key on it without touching the
# device; PE_LIMIT is the same table as a device array for traced scorers.
RATED_PE = (100_000, 3_000, 1_000)
PE_LIMIT = jnp.array(RATED_PE, dtype=jnp.int32)


# ---------------------------------------------------------------------------
# Endurance conversion helpers (DESIGN.md §2E). All host-side floats, keyed
# on the rated-endurance table above; JEDEC JESD218-style definitions:
#   TBW  = capacity × rated P/E ÷ WAF   (total host bytes writable)
#   DWPD = host bytes/day ÷ capacity    (drive writes per day)
#   lifetime = TBW ÷ host bytes/day     (years until rated wear exhausted)
# ---------------------------------------------------------------------------
_DAYS_PER_YEAR = 365.25


def tbw_bytes(capacity_bytes, rated_pe_cycles, waf=1.0):
    """Total host bytes writable before rated wear is exhausted.

    ``waf`` scales down writable host bytes: every host byte costs ``waf``
    physical bytes of programs, so TBW = capacity × P/E ÷ WAF.
    """
    return float(capacity_bytes) * float(rated_pe_cycles) / max(float(waf), 1e-12)


def dwpd(host_bytes_per_day, capacity_bytes):
    """Drive writes per day at the observed host write rate."""
    return float(host_bytes_per_day) / max(float(capacity_bytes), 1e-12)


def lifetime_years(tbw, host_bytes_per_day):
    """Years until ``tbw`` is exhausted at the observed host write rate.

    Returns 0.0 when no host writes were observed (lifetime undefined —
    the 0 sentinel keeps sweep rows JSON-finite).
    """
    if float(host_bytes_per_day) <= 0.0:
        return 0.0
    return float(tbw) / (float(host_bytes_per_day) * _DAYS_PER_YEAR)


def dwpd_for_lifetime(tbw, capacity_bytes, years):
    """Sustainable DWPD for a target lifetime: TBW ÷ (capacity × days)."""
    denom = max(float(capacity_bytes) * float(years) * _DAYS_PER_YEAR, 1e-12)
    return float(tbw) / denom

# ---------------------------------------------------------------------------
# Heat classes (paper §IV-A heat classifier).
# ---------------------------------------------------------------------------
COLD = 0
WARM = 1
HOT = 2
HEAT_NAMES = ("COLD", "WARM", "HOT")

# ---------------------------------------------------------------------------
# Wear stages (Table I) — QLC P/E-cycle bands.
# ---------------------------------------------------------------------------
STAGE_YOUNG = 0
STAGE_MIDDLE = 1
STAGE_OLD = 2
STAGE_NAMES = ("young", "middle", "old")
STAGE_BOUNDS = jnp.array([333, 666, 1_000_000], dtype=jnp.int32)


def stage_of(pe_cycles):
    """Map P/E-cycle counts to wear stages per Table I (young/middle/old)."""
    pe = jnp.asarray(pe_cycles)
    return jnp.where(pe <= 333, STAGE_YOUNG, jnp.where(pe <= 666, STAGE_MIDDLE, STAGE_OLD)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Layer-B tier view of the same ids (bf16 / int8 / int4).
# ---------------------------------------------------------------------------
TIER_BF16 = SLC
TIER_INT8 = TLC
TIER_INT4 = QLC
TIER_NAMES = ("bf16", "int8", "int4")
TIER_BITS = jnp.array([16, 8, 4], dtype=jnp.int32)
