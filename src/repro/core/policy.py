"""RARO migration principles — Table II of the paper.

| NAND | Access frequency | Retry count        | Conversion |
|------|------------------|--------------------|------------|
| QLC  | Hot              | >= R1              | QLC -> SLC |
| QLC  | Warm             | >= R2 (R2 >= R1)   | QLC -> TLC |
| TLC  | Hot              | >= R1              | TLC -> SLC |

plus the stage-dependent R2 schedule chosen by the paper's sensitivity study
(§V-C): R2 = 5 / 7 / 11 for young / middle / old, R1 = 1.

The decision function is pure and element-wise, so it is shared verbatim by
the SSD simulator (flash modes) and the KV-cache tier manager (precision
tiers) — see DESIGN.md §2B for the mapping.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import modes

# Paper §V-C: R1 = 1 because freshly converted TLC needs <= 1 retry.
DEFAULT_R1 = 1
# Paper Fig. 17/18 conclusion: R2 = 5 / 7 / 11 per wear stage.
R2_BY_STAGE = jnp.array([5, 7, 11], dtype=jnp.int32)


class Thresholds(NamedTuple):
    r1: jnp.ndarray  # int32 scalar or per-element
    r2: jnp.ndarray  # int32 scalar or per-element (r2 >= r1)


def stage_thresholds(pe_cycles, r1: int = DEFAULT_R1) -> Thresholds:
    """Per-element thresholds with the paper's stage-adaptive R2 schedule."""
    stage = modes.stage_of(pe_cycles)
    return Thresholds(jnp.int32(r1), R2_BY_STAGE[stage])


def migration_decision(mode, heat_cls, retries, th: Thresholds):
    """Table II, element-wise. Returns the *target* mode for every entry.

    Entries that do not trigger keep their current mode ("continue to
    maintain QLC storage to relegate relocation expenditure").
    """
    mode = jnp.asarray(mode, jnp.int32)
    heat_cls = jnp.asarray(heat_cls, jnp.int32)
    retries = jnp.asarray(retries, jnp.int32)

    qlc_hot = (mode == modes.QLC) & (heat_cls == modes.HOT) & (retries >= th.r1)
    qlc_warm = (mode == modes.QLC) & (heat_cls == modes.WARM) & (retries >= th.r2)
    tlc_hot = (mode == modes.TLC) & (heat_cls == modes.HOT) & (retries >= th.r1)

    target = mode
    target = jnp.where(qlc_warm, modes.TLC, target)
    # QLC->SLC takes precedence over QLC->TLC (hot beats warm by construction,
    # but keep the order explicit).
    target = jnp.where(qlc_hot, modes.SLC, target)
    target = jnp.where(tlc_hot, modes.SLC, target)
    return target


def hotness_only_decision(mode, heat_cls):
    """The paper's 'Hotness' comparison scheme: temperature-only 3-mode
    conversion, ignoring retry counts (used as the capacity-loss baseline)."""
    mode = jnp.asarray(mode, jnp.int32)
    heat_cls = jnp.asarray(heat_cls, jnp.int32)
    target = mode
    target = jnp.where((mode == modes.QLC) & (heat_cls == modes.WARM), modes.TLC, target)
    target = jnp.where((mode == modes.QLC) & (heat_cls == modes.HOT), modes.SLC, target)
    target = jnp.where((mode == modes.TLC) & (heat_cls == modes.HOT), modes.SLC, target)
    return target
