"""Raw-bit-error-rate model — Equation (1) of the paper.

``RBER(cycles, time, reads) = eps + alpha*cycles^k            (wear)
                             + beta*cycles^m * time^n          (retention)
                             + gamma*cycles^p * reads^q        (read disturb)``

Constants are per flash mode and were calibrated (see
``tests/test_retry_calibration.py`` and DESIGN.md §6) so that the Eq.-(3)
retry estimate lands in the paper's measured bands (Fig. 5/6):

  QLC  young 1–10 retries (bulk 4–9),  middle 5–13 (bulk 7–12),
       old 11–16 with ~9.7% of pages pinned at the table max of 16.
  TLC  far fewer retries than QLC at the same stage; a freshly converted
       TLC block sees <= 1 retry under typical load (paper §V-C), which is
       why the paper selects R1 = 1.
  SLC  effectively retry-free.

Per-page variation (3D-NAND layer-to-layer / process variation, §II-C) is
modelled as a deterministic lognormal multiplier keyed on the physical page
id, so the simulator is fully reproducible.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import modes


class RBERParams(NamedTuple):
    """Eq. (1) constants for one flash mode (all float32 scalars)."""

    eps: jnp.ndarray
    alpha: jnp.ndarray
    k: jnp.ndarray
    beta: jnp.ndarray
    m: jnp.ndarray
    n: jnp.ndarray
    gamma: jnp.ndarray
    p: jnp.ndarray
    q: jnp.ndarray


def _params(eps, alpha, k, beta, m, n, gamma, p, q) -> RBERParams:
    return RBERParams(*[jnp.float32(v) for v in (eps, alpha, k, beta, m, n, gamma, p, q)])


# ---------------------------------------------------------------------------
# Calibrated constants. "time" is hours since program; "reads" is reads to the
# page's block since program; "cycles" is block P/E count.
#
# QLC calibration anchors (n_sense=8, delta=0.2, E_LDPC=72/8192 -> see
# retry.py): retries ~= log_0.8(1.0986e-3 / RBER), so
#   RBER 4.2e-3  -> ~6 retries   (young centre)
#   RBER 8.2e-3  -> ~9 retries   (middle centre)
#   RBER 2.2e-2  -> ~13.5 retries (old centre; lognormal tail clips at 16)
# ---------------------------------------------------------------------------
MODE_RBER_PARAMS: dict[int, RBERParams] = {
    # SLC: wide noise margin; essentially flat and tiny.
    modes.SLC: _params(
        eps=1e-5, alpha=2e-9, k=1.0, beta=1e-11, m=1.0, n=0.5, gamma=1e-12, p=1.0, q=0.5
    ),
    # TLC: fresh (time~0, reads~0) RBER stays below the 1-retry point even at
    # +2.3 sigma page variation (eps + alpha*c <= ~9.6e-4 at c=500), which is
    # the paper's observation that freshly converted TLC needs <= 1 retry and
    # hence R1 = 1. Retention/disturb keep TLC well under QLC at equal stage.
    modes.TLC: _params(
        eps=6e-4, alpha=7e-7, k=1.0, beta=3.0e-10, m=1.6, n=0.7, gamma=4.3e-10, p=1.0, q=1.1
    ),
    # QLC anchors. Two regimes matter (paper §V-C chose R2 at the LOW end of
    # each stage's Fig.-6 band, i.e. lightly-stressed pages must sit BELOW
    # R2 while heavily-read blocks rise above it via read disturb):
    #   fresh/lightly-read (t~24h, r<~100):  young ~4, middle ~6, old ~9
    #     retries — below the 5/7/11 R2 schedule, so warm data in healthy
    #     blocks is NOT converted (RARO's capacity saving).
    #   heavily-read blocks (r ~2000+):      young ~6, middle ~10, old ~13
    #     retries — the Fig. 6 bulk bands; these DO convert.
    # Disturb is deliberately the steep term (q=1.1 in reads).
    modes.QLC: _params(
        eps=1.3e-3, alpha=3.2e-6, k=1.0, beta=3.25e-9, m=1.6, n=0.7, gamma=3.0e-9, p=1.0, q=1.1
    ),
}

# Stacked (N_MODES, 9) table so mode can be a traced array index.
_PARAM_TABLE = jnp.stack(
    [jnp.stack(MODE_RBER_PARAMS[m]) for m in range(modes.N_MODES)]
)  # (3, 9)

# Per-page lognormal variation of ln-RBER (DESIGN.md §6): sigma such that the
# retry spread matches the paper's per-stage bands (~±4 retries ~ 2 sigma).
PAGE_SIGMA = 0.40


def rber(mode, cycles, time_h, reads):
    """Eq. (1). All args broadcastable arrays; ``mode`` int in {0,1,2}."""
    P = _PARAM_TABLE[jnp.asarray(mode, jnp.int32)]  # (..., 9)
    eps, alpha, k, beta, m, n, gamma, p, q = [P[..., i] for i in range(9)]
    c = jnp.maximum(jnp.asarray(cycles, jnp.float32), 0.0)
    t = jnp.maximum(jnp.asarray(time_h, jnp.float32), 0.0)
    r = jnp.maximum(jnp.asarray(reads, jnp.float32), 0.0)
    wear = alpha * jnp.power(c, k)
    retention = beta * jnp.power(c, m) * jnp.power(t, n)
    disturb = gamma * jnp.power(c, p) * jnp.power(r, q)
    return eps + wear + retention + disturb


def page_variation(page_ids, sigma: float = PAGE_SIGMA):
    """Deterministic per-page lognormal factor (process variation).

    Uses a stateless hash -> standard normal so that the same physical page
    always has the same relative reliability, as real layer-to-layer
    variation does.
    """
    pid = jnp.asarray(page_ids, jnp.uint32)
    # xorshift-style integer hash
    h = pid * jnp.uint32(0x9E3779B9)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    # two 16-bit halves -> uniform (0,1) pair -> Box-Muller normal
    u1 = (jnp.float32(h & jnp.uint32(0xFFFF)) + 0.5) / 65536.0
    u2 = (jnp.float32((h >> 16) & jnp.uint32(0xFFFF)) + 0.5) / 65536.0
    z = jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(2.0 * jnp.pi * u2)
    return jnp.exp(sigma * z)


def page_rber(mode, cycles, time_h, reads, page_ids):
    """Eq. (1) with per-page process variation applied multiplicatively."""
    return rber(mode, cycles, time_h, reads) * page_variation(page_ids)
