"""Victim scoring and elastic capacity recovery (paper §IV-E, Fig. 12).

This module owns *all* victim selection in the simulator behind one entry
point, :func:`score_victims` — GC victim picking, reclaim demotion, and the
conversion paths share its top-k lane machinery, so a new scoring objective
is one formula here instead of three forked code paths (DESIGN.md §2E).

Objectives:

``"min_valid"``
    Classic greedy GC: fewest valid pages first. Pinned bit-identical to
    the historical inline selection in ``ftl.select_gc_victims``.
``"lifespan"``
    Wear-levelled GC: ``score = α·invalid_ratio − β·migration_cost −
    γ·pe_normalized`` where ``migration_cost`` is the valid fraction that
    must be relocated and ``pe_normalized`` is the block's P/E count over
    its mode's rated endurance. α/β/γ come from ``SimConfig``.
``"demotion"``
    Elastic capacity recovery: hot data migrated to SLC/TLC eventually
    cools; leaving it in low-density modes blocks the tiering path of new
    hot data and erodes capacity. Demotes the *coldest* low-density blocks
    back toward QLC, but only under free-space pressure, weighing (paper's
    words) "the remaining space of the device, the efficiency of rubbish
    collection, and the user's writing demand".

The GC objective is also selectable per-run as a traced ``RunKnobs`` axis
(``objective_code``): a ``jnp.where`` between the two formulas, so a vmapped
sweep batches both objectives in one compiled program. Code 0 (min-valid)
traces the identical selection ops as the static default.
"""

from __future__ import annotations

import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import modes

# Victim-scoring objectives. GC_OBJECTIVES (the statically configurable
# subset, mirrored by geometry.GC_OBJECTIVES for SimConfig validation) maps
# to integer codes for the traced RunKnobs sweep axis.
GC_MIN_VALID = "min_valid"
GC_LIFESPAN = "lifespan"
DEMOTION = "demotion"
GC_OBJECTIVES = (GC_MIN_VALID, GC_LIFESPAN)
GC_OBJECTIVE_CODES = {GC_MIN_VALID: 0, GC_LIFESPAN: 1}

_DEPRECATED_WARNED: set[str] = set()


def _warn_deprecated(name: str) -> None:
    if name in _DEPRECATED_WARNED:
        return
    _DEPRECATED_WARNED.add(name)
    warnings.warn(
        f"reclaim.{name} is deprecated; use reclaim.score_victims(...)",
        DeprecationWarning, stacklevel=3,
    )


class ReclaimConfig(NamedTuple):
    # Demote only when free capacity fraction drops below this watermark.
    low_watermark: float = 0.15
    # Stop demoting once free capacity recovers to this level.
    high_watermark: float = 0.25
    # A block is demotable only if every page in it is COLD for this many
    # consecutive epochs (hysteresis against sudden access-pattern changes).
    cold_epochs: int = 4
    # Cap on demotions per recovery pass (bounds write amplification).
    max_per_pass: int = 8


def demotion_scores(block_mode, block_heat, cold_age):
    """Score blocks for demotion: only SLC/TLC, colder + longer-cold first.

    Returns float scores; larger = better demotion candidate; -inf for
    ineligible blocks.
    """
    block_mode = jnp.asarray(block_mode, jnp.int32)
    eligible = block_mode < modes.QLC
    # Cold age dominates; residual heat breaks ties (colder wins).
    score = jnp.asarray(cold_age, jnp.float32) - 1e-3 * jnp.asarray(block_heat, jnp.float32)
    return jnp.where(eligible, score, -jnp.inf)


def _topk(scores, eligible, k: int):
    """Top-k victim lane selection shared by every objective: one
    ``lax.top_k`` over ``eligible``-masked float scores.

    Returns ``(victims, ok)``: ``k`` block ids ordered best-candidate-first
    (ties break to the lowest block id, matching a sequential greedy argmax)
    and a validity lane mask — a lane is dead when fewer than ``k`` blocks
    are eligible.
    """
    masked = jnp.where(eligible, jnp.asarray(scores, jnp.float32), -jnp.inf)
    vals, victims = jax.lax.top_k(masked, k)
    return victims.astype(jnp.int32), vals > -jnp.inf


def _demotion_select(block_mode, block_heat, cold_age, free_frac, cfg: ReclaimConfig):
    """Array-level demotion selection core (scores → hysteresis →
    watermark → top-k → one-level target)."""
    scores = demotion_scores(block_mode, block_heat, cold_age)
    eligible = (scores > -jnp.inf) & (jnp.asarray(cold_age) >= cfg.cold_epochs)
    under_pressure = jnp.asarray(free_frac) < cfg.low_watermark

    k = min(cfg.max_per_pass, block_mode.shape[-1])
    victims, ok = _topk(scores, eligible & under_pressure, k)
    target = jnp.minimum(jnp.asarray(block_mode, jnp.int32)[victims] + 1, modes.QLC)
    return victims, ok, target


def gc_scores(s, cfg, objective: str = GC_MIN_VALID, objective_code=None):
    """Per-block GC victim scores (larger = better victim).

    ``objective_code`` (a traced int32 scalar, see ``RunKnobs.gc_objective``)
    selects the formula inside the trace via ``jnp.where``; when ``None``
    the static ``objective`` string picks it at trace time. The min-valid
    branch traces exactly ``-block_valid.astype(f32)``, preserving
    bit-identity with the historical selection.
    """
    min_valid = -s.block_valid.astype(jnp.float32)
    if objective_code is None and objective == GC_MIN_VALID:
        return min_valid

    from repro.ssdsim import geometry  # deferred: core must stay importable alone

    pages = geometry.pages_per_block(cfg)[s.block_mode].astype(jnp.float32)
    migration_cost = s.block_valid.astype(jnp.float32) / pages
    invalid_ratio = 1.0 - migration_cost
    pe_norm = s.block_pe.astype(jnp.float32) / modes.PE_LIMIT[s.block_mode].astype(jnp.float32)
    lifespan = (cfg.gc_alpha * invalid_ratio
                - cfg.gc_beta * migration_cost
                - cfg.gc_gamma * pe_norm)
    if objective_code is None:
        return lifespan
    code = jnp.asarray(objective_code, jnp.int32)
    return jnp.where(code == GC_OBJECTIVE_CODES[GC_LIFESPAN], lifespan, min_valid)


def score_victims(s, cfg, objective: str = GC_MIN_VALID, *, k: int | None = None,
                  block_heat=None, free_frac=None, reclaim_cfg: ReclaimConfig | None = None,
                  objective_code=None):
    """Unified victim selection over an ``SSDState``.

    Returns ``(victims, ok, target)``: block-id lanes ordered
    best-candidate-first, a validity mask, and each victim's destination
    mode (its own mode for GC — same-density relocation — or one density
    level down for demotion).

    GC objectives (``"min_valid"``/``"lifespan"``) require ``k``; the
    ``"demotion"`` objective requires ``block_heat``, ``free_frac`` and
    ``reclaim_cfg`` (its k is ``reclaim_cfg.max_per_pass``).
    """
    if objective == DEMOTION:
        if block_heat is None or free_frac is None or reclaim_cfg is None:
            raise ValueError("demotion objective needs block_heat, free_frac, reclaim_cfg")
        from repro.ssdsim import state as st  # deferred: core must stay importable alone

        # Open (partially written) low-density blocks are not demotable:
        # treat them as QLC so demotion_scores masks them out.
        eligible_mode = jnp.where(s.block_state == st.FULL, s.block_mode, modes.QLC)
        return _demotion_select(eligible_mode, block_heat, s.block_cold_age,
                                free_frac, reclaim_cfg)

    if objective not in GC_OBJECTIVES:
        raise ValueError(f"unknown victim objective {objective!r}")
    if k is None:
        raise ValueError("GC objectives need an explicit k")
    from repro.ssdsim import geometry, state as st  # deferred imports, as above

    ppb = geometry.pages_per_block(cfg)
    reclaimable = (s.block_state == st.FULL) & (s.block_valid < ppb[s.block_mode])
    scores = gc_scores(s, cfg, objective, objective_code)
    victims, ok = _topk(scores, reclaimable, k)
    target = s.block_mode[victims]  # GC relocates at the victim's own density
    return victims, ok, target


# ---------------------------------------------------------------------------
# Deprecated wrappers (pre-score_victims API). Thin shims over the shared
# selection core; equivalence is pinned by tests/test_endurance.py.
# ---------------------------------------------------------------------------

def select_demotions(block_mode, block_heat, cold_age, free_frac, cfg: ReclaimConfig):
    """Deprecated dense-mask demotion API — use :func:`score_victims`.

    Returns (mask, target_mode): ``mask[b]`` true if block b is demoted this
    pass; ``target_mode[b]`` its new mode (SLC->TLC->QLC one level per pass,
    the paper's fine-grained multi-mode conversion in reverse).
    """
    _warn_deprecated("select_demotions")
    victims, ok, _ = _demotion_select(block_mode, block_heat, cold_age, free_frac, cfg)
    n_blocks = jnp.asarray(block_mode).shape[-1]
    mask = jnp.zeros((n_blocks,), bool).at[jnp.where(ok, victims, n_blocks)].set(
        True, mode="drop")
    target = jnp.where(mask, jnp.minimum(jnp.asarray(block_mode, jnp.int32) + 1, modes.QLC),
                       block_mode)
    return mask, target


def topk_victims(scores, eligible, k: int):
    """Deprecated — use :func:`score_victims` (or its ``_topk`` core)."""
    _warn_deprecated("topk_victims")
    return _topk(scores, eligible, k)


def select_demotion_victims(block_mode, block_heat, cold_age, free_frac,
                            cfg: ReclaimConfig):
    """Deprecated lane-based demotion API — use
    ``score_victims(s, cfg, "demotion", ...)``, which also folds in the
    open-block eligibility mask the engine used to compute by hand.

    Returns ``(victims, ok, target)``: up to ``max_per_pass`` block ids
    ordered best-candidate-first, a validity lane mask, and each victim's
    one-level demotion target mode.
    """
    _warn_deprecated("select_demotion_victims")
    return _demotion_select(block_mode, block_heat, cold_age, free_frac, cfg)
