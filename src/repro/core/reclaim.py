"""Elastic capacity recovery (paper §IV-E, Fig. 12).

Hot data migrated to SLC/TLC eventually cools; leaving it in low-density
modes blocks the tiering path of new hot data and erodes capacity. The
recovery policy demotes the *coldest* low-density blocks back toward QLC,
but only under free-space pressure, weighing (paper's words) "the remaining
space of the device, the efficiency of rubbish collection, and the user's
writing demand".
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import modes


class ReclaimConfig(NamedTuple):
    # Demote only when free capacity fraction drops below this watermark.
    low_watermark: float = 0.15
    # Stop demoting once free capacity recovers to this level.
    high_watermark: float = 0.25
    # A block is demotable only if every page in it is COLD for this many
    # consecutive epochs (hysteresis against sudden access-pattern changes).
    cold_epochs: int = 4
    # Cap on demotions per recovery pass (bounds write amplification).
    max_per_pass: int = 8


def demotion_scores(block_mode, block_heat, cold_age):
    """Score blocks for demotion: only SLC/TLC, colder + longer-cold first.

    Returns float scores; larger = better demotion candidate; -inf for
    ineligible blocks.
    """
    block_mode = jnp.asarray(block_mode, jnp.int32)
    eligible = block_mode < modes.QLC
    # Cold age dominates; residual heat breaks ties (colder wins).
    score = jnp.asarray(cold_age, jnp.float32) - 1e-3 * jnp.asarray(block_heat, jnp.float32)
    return jnp.where(eligible, score, -jnp.inf)


def select_demotions(block_mode, block_heat, cold_age, free_frac, cfg: ReclaimConfig):
    """Pick up to ``max_per_pass`` blocks to demote one density level.

    Returns (mask, target_mode): ``mask[b]`` true if block b is demoted this
    pass; ``target_mode[b]`` its new mode (SLC->TLC->QLC one level per pass,
    the paper's fine-grained multi-mode conversion in reverse).
    """
    scores = demotion_scores(block_mode, block_heat, cold_age)
    eligible = (scores > -jnp.inf) & (jnp.asarray(cold_age) >= cfg.cold_epochs)
    under_pressure = jnp.asarray(free_frac) < cfg.low_watermark

    # Top-k by score among eligible blocks.
    k = min(cfg.max_per_pass, block_mode.shape[-1])
    masked = jnp.where(eligible, scores, -jnp.inf)
    _, top_idx = jax.lax.top_k(masked, k)
    mask = jnp.zeros(block_mode.shape, bool).at[top_idx].set(True)
    mask = mask & eligible & under_pressure

    target = jnp.where(mask, jnp.minimum(jnp.asarray(block_mode, jnp.int32) + 1, modes.QLC), block_mode)
    return mask, target


def topk_victims(scores, eligible, k: int):
    """Shared top-k victim lane selection for the fused background-FTL
    passes (reclaim demotion and multi-victim GC): one ``lax.top_k`` over
    ``eligible``-masked float scores.

    Returns ``(victims, ok)``: ``k`` block ids ordered best-candidate-first
    (ties break to the lowest block id, matching a sequential greedy argmax)
    and a validity lane mask — a lane is dead when fewer than ``k`` blocks
    are eligible.
    """
    masked = jnp.where(eligible, jnp.asarray(scores, jnp.float32), -jnp.inf)
    vals, victims = jax.lax.top_k(masked, k)
    return victims.astype(jnp.int32), vals > -jnp.inf


def select_demotion_victims(block_mode, block_heat, cold_age, free_frac,
                            cfg: ReclaimConfig):
    """Fused victim selection for the engine hot path: one ``lax.top_k``
    replaces the per-candidate argmax loop of the dense-mask API above.

    Returns ``(victims, ok, target)``: up to ``max_per_pass`` block ids
    ordered best-candidate-first, a validity lane mask, and each victim's
    one-level demotion target mode. Selection semantics match
    :func:`select_demotions` (same scores, hysteresis and watermark).
    """
    scores = demotion_scores(block_mode, block_heat, cold_age)
    eligible = (scores > -jnp.inf) & (jnp.asarray(cold_age) >= cfg.cold_epochs)
    under_pressure = jnp.asarray(free_frac) < cfg.low_watermark

    k = min(cfg.max_per_pass, block_mode.shape[-1])
    victims, ok = topk_victims(scores, eligible & under_pressure, k)
    target = jnp.minimum(jnp.asarray(block_mode, jnp.int32)[victims] + 1, modes.QLC)
    return victims, ok, target
