"""Read-retry model — Equations (2)/(3) of the paper.

``(a * RBER * n_SENSE) * (1 - delta)^n_RETRY <= E_LDPC``            (2)
``n_RETRY >= log_{1-delta}( E_LDPC / (a * RBER * n_SENSE) )``        (3)

with delta = 0.2 (each retry drops the effective RBER to 80%) and
E_LDPC = 72 correctable bits per 1 KiB (8192-bit) codeword, i.e. a
correctable error *rate* of 72/8192 ~= 8.789e-3 (paper §II-D).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import modes, rber as rber_mod

DELTA = 0.2
E_LDPC_BITS = 72.0
CODEWORD_BITS = 8192.0  # 1 KiB codeword
E_LDPC_RATE = E_LDPC_BITS / CODEWORD_BITS
ALPHA_ADJ = 1.0  # Eq.(2) adjacent-voltage-state factor `a`


def expected_retries(rber, n_sense, *, delta: float = DELTA, e_ldpc: float = E_LDPC_RATE,
                     a: float = ALPHA_ADJ):
    """Continuous Eq.-(3) retry estimate (>= 0, unclipped)."""
    rber = jnp.asarray(rber, jnp.float32)
    n_sense = jnp.asarray(n_sense, jnp.float32)
    raw = jnp.log(e_ldpc / jnp.maximum(a * rber * n_sense, 1e-30)) / jnp.log(1.0 - delta)
    return jnp.maximum(raw, 0.0)


def retry_count(mode, rber, *, delta: float = DELTA, e_ldpc: float = E_LDPC_RATE,
                a: float = ALPHA_ADJ):
    """Integer retries for a page of ``mode`` with raw error rate ``rber``.

    Ceil of Eq. (3), clipped to the device retry-table limit for the mode.
    A page whose first sense already satisfies LDPC (RBER*n_sense <= E) needs
    zero retries.
    """
    mode = jnp.asarray(mode, jnp.int32)
    n_sense = modes.N_SENSE[mode]
    cont = expected_retries(rber, n_sense, delta=delta, e_ldpc=e_ldpc, a=a)
    n = jnp.ceil(cont).astype(jnp.int32)
    return jnp.clip(n, 0, modes.MAX_RETRIES[mode])


def page_retries(mode, cycles, time_h, reads, page_ids):
    """Full pipeline: Eq.(1) per-page RBER -> Eq.(3) retry count."""
    r = rber_mod.page_rber(mode, cycles, time_h, reads, page_ids)
    return retry_count(mode, r)


def read_latency_us(mode, n_retries):
    """Service time of a page read: base sense + one extra sense per retry.

    Matches the paper's Fig. 4 measurements: for QLC, 1 retry halves
    bandwidth (2x latency) and 10 retries cut it ~91-92% (11x latency).
    """
    base = modes.READ_LATENCY_US[jnp.asarray(mode, jnp.int32)]
    return base * (1.0 + jnp.asarray(n_retries, jnp.float32))
