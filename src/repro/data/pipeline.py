"""Synthetic-but-learnable token pipeline.

Deterministic and STATELESS-RESUMABLE: batch t is a pure function of
(seed, t), so restoring a checkpoint at step t resumes the exact data
stream with no pipeline state to persist beyond the step counter — the
property elastic restarts need. Data is host-sharded: each data-parallel
host materializes only its slice.

The stream has learnable structure (noisy modular-affine next-token rule),
so a ~100M model's loss drops well below ln(vocab) within a few hundred
steps — used by the end-to-end training example.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.05
    mult: int = 31
    add: int = 7


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int, shard: int = 0, n_shards: int = 1):
        """Batch for ``step``; host ``shard`` of ``n_shards`` gets rows
        [shard * b/n : (shard+1) * b/n]."""
        cfg = self.cfg
        b = cfg.global_batch // n_shards
        rng = np.random.default_rng((cfg.seed, step, shard))
        toks = np.empty((b, cfg.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab, b)
        noise = rng.random((b, cfg.seq_len)) < cfg.noise
        rand = rng.integers(0, cfg.vocab, (b, cfg.seq_len))
        for t in range(cfg.seq_len):
            nxt = (toks[:, t] * cfg.mult + cfg.add) % cfg.vocab
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
