# Experiments subsystem (DESIGN.md §7): scenario library + block-trace
# replay + vmapped sweep orchestration + tail-latency reporting. A new layer
# between the simulator core (repro.ssdsim) and the benchmark harness
# (benchmarks.run): the core stays single-run and knob-static, the harness
# stays print-only, and everything batched/multi-workload lives here.
from repro.experiments import registry, scenarios, sweep, traces  # noqa: F401
