"""Named scenario registry (DESIGN.md §7.2).

A *scenario* is any callable ``(cfg, n_requests, seed, **kw) -> trace`` where
``trace`` is the engine's packed ``{"lpn": (C, chunk), "op": (C, chunk)}``
dict. Generators register themselves by name so the sweep runner, the
benchmark harness and the CLI all share one namespace; the classic
``workload`` generators are registered here too so old and new workloads are
uniformly addressable.
"""

from __future__ import annotations

from typing import Callable

from repro.ssdsim import geometry, workload

SCENARIOS: dict[str, Callable] = {}
_SEED_INVARIANT: set[str] = set()


def register(name: str, seed_invariant: bool = False):
    """Decorator: register a trace builder under ``name`` (unique).

    ``seed_invariant`` marks builders whose trace does not depend on the
    seed (e.g. deterministic replay); the sweep runner warns when such a
    scenario is swept over multiple seeds, since the runs would be
    duplicates reported as seed variance.
    """

    def deco(fn: Callable) -> Callable:
        if name in SCENARIOS:
            raise ValueError(f"scenario {name!r} already registered")
        SCENARIOS[name] = fn
        if seed_invariant:
            _SEED_INVARIANT.add(name)
        return fn

    return deco


def is_seed_invariant(name: str) -> bool:
    return name in _SEED_INVARIANT


def names() -> list[str]:
    return sorted(SCENARIOS)


def build(name: str, cfg: geometry.SimConfig, n_requests: int, seed: int = 0, **kw):
    """Build the named scenario's packed trace."""
    try:
        fn = SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; have {names()}") from None
    return fn(cfg, n_requests, seed=seed, **kw)


# --- classic single-distribution workloads, re-exported by name ------------

@register("zipf")
def _zipf(cfg, n_requests, seed=0, theta=1.2, **kw):
    return workload.zipf_read_trace(cfg, n_requests, theta, seed=seed, **kw)


@register("uniform")
def _uniform(cfg, n_requests, seed=0):
    return workload.uniform_read_trace(cfg, n_requests, seed=seed)


@register("seq", seed_invariant=True)
def _seq(cfg, n_requests, seed=0, start=0):
    return workload.seq_read_trace(cfg, n_requests, start=start)


@register("mixed")
def _mixed(cfg, n_requests, seed=0, theta=1.2, read_frac=0.7):
    return workload.mixed_trace(cfg, n_requests, theta, read_frac=read_frac, seed=seed)
