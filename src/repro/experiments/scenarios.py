"""Synthetic scenario library (DESIGN.md §7.2).

Five workload families beyond the classic FIO-style distributions, each
chosen to stress a different part of the conversion policy:

  hotspot_shift         — the hot set *moves*: conversions made for the old
                          hotspot become stale capacity loss (reclaim test).
  bursty                — on/off traffic: intense bursts on a small hot set
                          separated by sparse background reads (heat decay).
  diurnal               — skew oscillates like day/night phases: popularity
                          concentrates and disperses smoothly.
  write_burst_then_read — a bulk ingest then a read-mostly phase: fresh
                          pages have low retention error, so early
                          conversions are wasteful (retry-awareness test).
  read_disturb_hammer   — a tiny LPN range is hammered so its blocks' read
                          counts explode: the paper's core motivation, where
                          disturb-driven retries ruin tail latency and
                          retry-aware migration pays off most.

All generators are host-side numpy (like repro.ssdsim.workload), fully
deterministic under a fixed seed, and return engine-ready packed traces.
"""

from __future__ import annotations

import numpy as np

from repro.experiments.registry import register
from repro.ssdsim import geometry, workload
from repro.ssdsim.engine import OP_READ, OP_WRITE


@register("hotspot_shift")
def hotspot_shift(cfg: geometry.SimConfig, n_requests: int, seed: int = 0,
                  n_phases: int = 4, hot_frac: float = 0.05,
                  hot_prob: float = 0.9):
    """Reads with a contiguous hotspot that jumps to a new region each phase.

    Within a phase, ``hot_prob`` of requests land uniformly in the current
    hotspot (``hot_frac`` of the logical space); the rest are uniform over
    the whole device.
    """
    rng = np.random.default_rng(seed)
    L = cfg.n_logical
    hot_n = max(int(L * hot_frac), 1)
    per_phase = -(-n_requests // n_phases)
    lpn = np.empty(n_requests, np.int64)
    for ph in range(n_phases):
        lo, hi = ph * per_phase, min((ph + 1) * per_phase, n_requests)
        if lo >= hi:
            break
        start = (ph * (L // n_phases)) % max(L - hot_n, 1)
        n = hi - lo
        is_hot = rng.random(n) < hot_prob
        seg = np.where(
            is_hot,
            start + rng.integers(0, hot_n, size=n),
            rng.integers(0, L, size=n),
        )
        lpn[lo:hi] = seg
    return workload._pack(cfg, lpn.astype(np.int32), np.full(n_requests, OP_READ, np.int32))


@register("bursty")
def bursty(cfg: geometry.SimConfig, n_requests: int, seed: int = 0,
           burst_len: int = 2048, idle_len: int = 2048,
           hot_frac: float = 0.02, theta: float = 1.2):
    """On/off traffic: Zipf bursts over a small hot set, then sparse uniform
    background reads while the burst set cools (exercises heat decay and the
    reclaim hysteresis)."""
    rng = np.random.default_rng(seed)
    L = cfg.n_logical
    hot_n = max(int(L * hot_frac), 1)
    hot_set = rng.permutation(L)[:hot_n]
    p = workload.zipf_probs(hot_n, theta)
    lpn = np.empty(n_requests, np.int64)
    i, on = 0, True
    while i < n_requests:
        n = min(burst_len if on else idle_len, n_requests - i)
        if on:
            lpn[i:i + n] = hot_set[rng.choice(hot_n, size=n, p=p)]
        else:
            lpn[i:i + n] = rng.integers(0, L, size=n)
        i += n
        on = not on
    return workload._pack(cfg, lpn.astype(np.int32), np.full(n_requests, OP_READ, np.int32))


@register("diurnal")
def diurnal(cfg: geometry.SimConfig, n_requests: int, seed: int = 0,
            n_cycles: int = 2, n_segments: int = 32,
            theta_lo: float = 0.6, theta_hi: float = 1.4):
    """Skew oscillates sinusoidally between ``theta_lo`` (dispersed,
    night-time scans) and ``theta_hi`` (concentrated, day-time serving)
    across ``n_cycles`` day/night phases."""
    rng = np.random.default_rng(seed)
    L = cfg.n_logical
    perm = rng.permutation(L)
    per_seg = -(-n_requests // n_segments)
    lpn = np.empty(n_requests, np.int64)
    for seg in range(n_segments):
        lo, hi = seg * per_seg, min((seg + 1) * per_seg, n_requests)
        if lo >= hi:
            break
        phase = 2.0 * np.pi * n_cycles * seg / n_segments
        theta = theta_lo + (theta_hi - theta_lo) * 0.5 * (1.0 + np.sin(phase))
        p = workload.zipf_probs(L, theta)
        lpn[lo:hi] = perm[rng.choice(L, size=hi - lo, p=p)]
    return workload._pack(cfg, lpn.astype(np.int32), np.full(n_requests, OP_READ, np.int32))


@register("write_burst_then_read")
def write_burst_then_read(cfg: geometry.SimConfig, n_requests: int, seed: int = 0,
                          write_frac: float = 0.3, theta: float = 1.2):
    """Bulk ingest then read-mostly serving: the first ``write_frac`` of the
    trace uniformly overwrites pages, the remainder Zipf-reads the device.
    Freshly rewritten pages have near-zero retention/disturb error, so a
    retry-aware policy should convert far less than a temperature-only one.
    """
    rng = np.random.default_rng(seed)
    L = cfg.n_logical
    n_w = int(n_requests * write_frac)
    w_lpn = rng.integers(0, L, size=n_w)
    p = workload.zipf_probs(L, theta)
    perm = rng.permutation(L)
    r_lpn = perm[rng.choice(L, size=n_requests - n_w, p=p)]
    lpn = np.concatenate([w_lpn, r_lpn]).astype(np.int32)
    op = np.concatenate([
        np.full(n_w, OP_WRITE, np.int32),
        np.full(n_requests - n_w, OP_READ, np.int32),
    ])
    return workload._pack(cfg, lpn, op)


@register("fault_storm")
def fault_storm(cfg: geometry.SimConfig, n_requests: int, seed: int = 0,
                theta: float = 1.2, read_frac: float = 0.3,
                write_theta: float = 2.0):
    """Write-heavy Zipf overwrites plus skewed re-reads: the workload shape
    under which every injected fault class (DESIGN.md §2D) actually fires.
    Concentrated overwrites manufacture GC victims, so erases happen at a
    steady rate (erase failures -> bad-block retirement), the write stream
    exercises program failures and the re-placement path, and the hot read
    set keeps hammering aged pages (uncorrectable reads once a retry budget
    is set). The elevated P/E cycles and the fault rates themselves ride on
    the config / sweep fault axes — pair this trace with
    ``configs.raro_ssd.fault_storm_sweep``."""
    return workload.mixed_trace(cfg, n_requests, theta, read_frac=read_frac,
                                seed=seed, write_theta=write_theta)


@register("zipf_openloop")
def zipf_openloop(cfg: geometry.SimConfig, n_requests: int, seed: int = 0,
                  theta: float = 1.2, rate_iops: float = 50_000.0,
                  arrival_dist: str = "poisson"):
    """Zipf reads with open-loop Poisson (or constant-rate) arrivals at
    ``rate_iops``. The base scenario for latency-vs-offered-load curves:
    sweep the offered load via ``RunKnobs.arrival_scale`` (a traced rate
    multiplier) so every load point batches through one compiled run."""
    tr = workload.zipf_read_trace(cfg, n_requests, theta, seed=seed)
    return workload.attach_arrivals(cfg, tr, rate_iops, dist=arrival_dist,
                                    seed=seed + 1)


@register("hammer_openloop")
def hammer_openloop(cfg: geometry.SimConfig, n_requests: int, seed: int = 0,
                    hammer_pages: int | None = None, hammer_prob: float = 0.8,
                    rate_iops: float = 50_000.0,
                    arrival_dist: str = "poisson"):
    """Read-disturb hammer with open-loop arrivals — the paper's tail-latency
    story under real queueing: disturb-driven retries inflate service times,
    which inflate queueing delay on the hammered LUNs, which is exactly the
    effect the closed-loop engine cannot show."""
    tr = read_disturb_hammer(cfg, n_requests, seed=seed,
                             hammer_pages=hammer_pages,
                             hammer_prob=hammer_prob)
    return workload.attach_arrivals(cfg, tr, rate_iops, dist=arrival_dist,
                                    seed=seed + 1)


@register("read_disturb_hammer")
def read_disturb_hammer(cfg: geometry.SimConfig, n_requests: int, seed: int = 0,
                        hammer_pages: int | None = None,
                        hammer_prob: float = 0.8):
    """Hammer a tiny contiguous LPN range (a few physical blocks under the
    sequential pre-fill) so those blocks' read counts — and hence their
    disturb-driven retry counts — explode, while background reads stay
    uniform. The scenario where retry-aware SLC promotion matters most for
    p99: a baseline device keeps re-reading ever-slower QLC pages.
    """
    rng = np.random.default_rng(seed)
    L = cfg.n_logical
    if hammer_pages is None:
        hammer_pages = max(2 * cfg.slots_per_block, 1)  # ~2 QLC blocks
    hammer_pages = min(hammer_pages, L)
    start = int(rng.integers(0, max(L - hammer_pages, 1)))
    n = n_requests
    is_hammer = rng.random(n) < hammer_prob
    lpn = np.where(
        is_hammer,
        start + rng.integers(0, hammer_pages, size=n),
        rng.integers(0, L, size=n),
    )
    return workload._pack(cfg, lpn.astype(np.int32), np.full(n, OP_READ, np.int32))
