"""Vmapped sweep orchestration (DESIGN.md §7.3).

Runs a (policy x wear x seed x knob x scenario) grid through the simulator
with one compiled program per *static* group. The split:

  batched through ``jax.vmap`` (one jit, stacked run axis):
      seeds / scenario draws (different traces, same shape),
      ``r1``, ``r2_override``, ``initial_pe``  (RunKnobs — traced scalars)
  looped in Python (change trace shapes or compiled branches):
      policy, geometry/SimConfig, scenario name, request count

so the canonical 2-policy x 2-wear x 2-seed grid compiles exactly twice and
executes 4 runs per dispatch. Results are per-run dicts (engine.summarize +
run metadata) and optional ``BENCH_*.json`` artifacts in the harness's
``name,value,unit`` row format.
"""

from __future__ import annotations

import itertools
import json
import warnings
from dataclasses import dataclass, field, replace
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.experiments import registry
from repro.ssdsim import engine, geometry, policies
from repro.ssdsim import state as st


@dataclass(frozen=True)
class SweepSpec:
    """A full experiment grid (cross product of every axis)."""

    scenario: str = "zipf"
    n_requests: int = 20_000
    policies: tuple[int, ...] = (geometry.BASELINE, geometry.RARO)
    initial_pe: tuple[int, ...] = (166, 833)
    seeds: tuple[int, ...] = (0, 1)
    r1: tuple[int, ...] = (1,)
    r2_override: tuple[int, ...] = (-1,)
    # offered-load multipliers for open-loop scenarios (traces carrying
    # arrival_ms): effective arrival time = trace arrival / scale, so the
    # whole latency-vs-load curve batches through one compiled program.
    # Ignored (with a warning) for closed-loop scenarios.
    arrival_scale: tuple[float, ...] = (1.0,)
    # forwarded to the scenario builder (e.g. {"theta": 1.2}); tuple-of-items
    # so the spec stays hashable
    scenario_kw: tuple[tuple[str, object], ...] = ()
    base: geometry.SimConfig = field(default_factory=geometry.SimConfig)

    def n_runs(self) -> int:
        return (len(self.policies) * len(self.initial_pe) * len(self.seeds)
                * len(self.r1) * len(self.r2_override)
                * len(self.arrival_scale))


@dataclass(frozen=True)
class RunSpec:
    """One point of the grid."""

    scenario: str
    policy: int
    initial_pe: int
    seed: int
    r1: int
    r2_override: int
    arrival_scale: float = 1.0

    def tag(self) -> str:
        parts = [
            self.scenario,
            geometry.POLICY_NAMES[self.policy],
            f"pe{self.initial_pe}",
            f"seed{self.seed}",
        ]
        if self.r1 != 1:
            parts.append(f"r1_{self.r1}")
        if self.r2_override >= 0:
            parts.append(f"r2_{self.r2_override}")
        if self.arrival_scale != 1.0:
            parts.append(f"load{self.arrival_scale:g}")
        return "_".join(parts)


def expand(spec: SweepSpec) -> list[RunSpec]:
    return [
        RunSpec(spec.scenario, pol, pe, seed, r1, r2, scale)
        for pol, pe, seed, r1, r2, scale in itertools.product(
            spec.policies, spec.initial_pe, spec.seeds, spec.r1,
            spec.r2_override, spec.arrival_scale
        )
    ]


@partial(jax.jit, static_argnums=(0, 3))
def _sweep_jit(cfg: geometry.SimConfig, lpns, ops, has_writes: bool,
               knobs: policies.RunKnobs, arrival_ms=None):
    """Run a stacked batch of traces; everything dynamic rides the vmap axis.

    ``lpns``/``ops``: (R, n_chunks, chunk); ``knobs``: (R,) fields;
    ``arrival_ms``: (R, n_chunks, chunk) f32 or None (closed loop). Returns
    the stacked final state pytree (leading run axis on every leaf).
    """

    def one(lpns_i, ops_i, knobs_i, arr_i=None):
        s0 = st.init_state(cfg, initial_pe=knobs_i.initial_pe)

        def body(s, x):
            return engine.step_chunk(s, x, cfg, has_writes, knobs_i)

        xs = (lpns_i, ops_i) if arr_i is None else (lpns_i, ops_i, arr_i)
        s, _ = lax.scan(body, s0, xs)
        return s

    if arrival_ms is None:
        return jax.vmap(one)(lpns, ops, knobs)
    return jax.vmap(one)(lpns, ops, knobs, arrival_ms)


def _take_run(stacked, i: int):
    return jax.tree_util.tree_map(lambda x: x[i], stacked)


def run_sweep(spec: SweepSpec, threads: int = 4, verbose: bool = False):
    """Execute the grid. Returns one result dict per run: everything from
    ``engine.summarize`` (mean + p50/p95/p99/p999 read latency, IOPS,
    capacity, ...) plus the run's metadata under ``"run"``.
    """
    runs = expand(spec)
    kw = dict(spec.scenario_kw)
    if len(spec.seeds) > 1 and registry.is_seed_invariant(spec.scenario):
        warnings.warn(
            f"scenario {spec.scenario!r} is deterministic w.r.t. seed; "
            f"{len(spec.seeds)} seeds will produce identical runs",
            stacklevel=2,
        )

    # traces depend only on (scenario, seed): build each once, share across
    # policies/knobs
    traces: dict[int, dict] = {}
    for seed in spec.seeds:
        traces[seed] = registry.build(
            spec.scenario, spec.base, spec.n_requests, seed=seed, **kw
        )
    has_writes = bool(any((t["op"] == engine.OP_WRITE).any() for t in traces.values()))
    open_loop = all("arrival_ms" in t for t in traces.values())
    if spec.arrival_scale != (1.0,) and not open_loop:
        warnings.warn(
            f"scenario {spec.scenario!r} has no arrival timestamps; the "
            f"arrival_scale axis {spec.arrival_scale} has no effect on "
            "closed-loop runs",
            stacklevel=2,
        )

    results = []
    for pol in spec.policies:  # static axis -> one compile each
        group = [r for r in runs if r.policy == pol]
        cfg = replace(spec.base, policy=pol)
        lpns = jnp.stack([jnp.asarray(traces[r.seed]["lpn"], jnp.int32) for r in group])
        ops = jnp.stack([jnp.asarray(traces[r.seed]["op"], jnp.int32) for r in group])
        arr = (
            jnp.stack([jnp.asarray(traces[r.seed]["arrival_ms"], jnp.float32)
                       for r in group])
            if open_loop else None
        )
        knobs = policies.RunKnobs(
            r1=jnp.asarray([r.r1 for r in group], jnp.int32),
            r2_override=jnp.asarray([r.r2_override for r in group], jnp.int32),
            initial_pe=jnp.asarray([r.initial_pe for r in group], jnp.int32),
            arrival_scale=(
                jnp.asarray([r.arrival_scale for r in group], jnp.float32)
                if open_loop else None
            ),
        )
        if verbose:
            print(f"# sweep group policy={geometry.POLICY_NAMES[pol]}: "
                  f"{len(group)} runs in one jit", flush=True)
        states = _sweep_jit(cfg, lpns, ops, has_writes, knobs, arr)
        for i, r in enumerate(group):
            m = engine.summarize(_take_run(states, i), cfg, threads=threads)
            m["run"] = dict(
                scenario=r.scenario,
                policy=geometry.POLICY_NAMES[r.policy],
                initial_pe=r.initial_pe,
                seed=r.seed,
                r1=r.r1,
                r2_override=r.r2_override,
                arrival_scale=r.arrival_scale,
                n_requests=spec.n_requests,
                tag=r.tag(),
            )
            results.append(m)
    return results


# --------------------------- result artifacts ------------------------------

_ROW_UNITS = {
    "iops": "IOPS",
    "mean_read_latency_us": "us",
    "read_lat_p50_us": "us",
    "read_lat_p95_us": "us",
    "read_lat_p99_us": "us",
    "read_lat_p999_us": "us",
    "write_lat_p50_us": "us",
    "write_lat_p95_us": "us",
    "write_lat_p99_us": "us",
    "write_lat_p999_us": "us",
    "read_queue_delay_us": "us",
    "retries_per_read": "retries",
    "capacity_gib": "GiB",
    "capacity_loss_gib": "GiB",
    "migrated_pages": "pages",
    "erases": "erases",
    "reads": "reads",
    "writes": "writes",
}


def result_rows(res: dict, prefix: str = "sweep"):
    """Flatten one run result into harness-style (name, value, unit) rows."""
    tag = res["run"]["tag"]
    return [
        (f"{prefix}/{tag}/{k}", float(res[k]), u)
        for k, u in _ROW_UNITS.items()
        if k in res
    ]


def write_artifacts(results, out_dir, prefix: str = "sweep") -> list[Path]:
    """One ``BENCH_<tag>.json`` per run, mirroring the harness CSV rows so
    artifacts and stdout stay diffable against each other."""
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    paths = []
    for res in results:
        doc = {
            "name": f"{prefix}/{res['run']['tag']}",
            "run": res["run"],
            "metrics": {
                k: (np.asarray(v).tolist() if isinstance(v, np.ndarray) else float(v))
                for k, v in res.items()
                if k != "run"
            },
            "rows": [list(r) for r in result_rows(res, prefix)],
        }
        p = out / f"BENCH_{prefix}_{res['run']['tag']}.json"
        p.write_text(json.dumps(doc, indent=1, sort_keys=True))
        paths.append(p)
    return paths
