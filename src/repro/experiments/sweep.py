"""Device-sharded sweep orchestration (DESIGN.md §7.3).

Runs a (policy x wear x seed x knob x scenario) grid through the simulator
with one compiled program per *static* group. The split:

  batched along a stacked run axis (one jit per group):
      seeds / scenario draws (different traces, same shape),
      ``r1``, ``r2_override``, ``initial_pe``  (RunKnobs — traced scalars)
  looped in Python (change trace shapes or compiled branches):
      policy, geometry/SimConfig, scenario name, request count

The stacked run axis executes either on a single device through ``jax.vmap``
(``devices=None``, the original path) or sharded across a 1-D device mesh
via ``shard_map`` (``devices=N`` / a device list): each device runs the
identical vmapped program on its slice of the runs, so the results match the
single-device path bit for bit. Grids that don't divide the device count are
padded with dummy replicas of the last run; the pads are dropped on the host
and never summarized.

Dispatch is asynchronous: every policy group is traced/compiled and enqueued
before any result is awaited, so group k+1's compile overlaps group k's
execution. Summarization happens afterwards, off the dispatch critical path
— one batched ``jax.device_get`` of the stacked final states per group, then
a host-side ``engine.summarize`` loop over numpy leaves.

Results are per-run dicts (engine.summarize + run metadata) and optional
``BENCH_*.json`` artifacts in the harness's ``name,value,unit`` row format.
"""

from __future__ import annotations

import itertools
import json
import time
import warnings
from dataclasses import dataclass, field, replace
from functools import partial
from pathlib import Path

import jax
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import reclaim
from repro.experiments import registry
from repro.ssdsim import engine, geometry, metrics_schema, policies
from repro.ssdsim import state as st


@dataclass(frozen=True)
class SweepSpec:
    """A full experiment grid (cross product of every axis)."""

    scenario: str = "zipf"
    n_requests: int = 20_000
    policies: tuple[int, ...] = (geometry.BASELINE, geometry.RARO)
    initial_pe: tuple[int, ...] = (166, 833)
    seeds: tuple[int, ...] = (0, 1)
    r1: tuple[int, ...] = (1,)
    r2_override: tuple[int, ...] = (-1,)
    # offered-load multipliers for open-loop scenarios (traces carrying
    # arrival_ms): effective arrival time = trace arrival / scale, so the
    # whole latency-vs-load curve batches through one compiled program.
    # Ignored (with a warning) for closed-loop scenarios.
    arrival_scale: tuple[float, ...] = (1.0,)
    # fault-injection axes (DESIGN.md §2D), batched through RunKnobs like
    # the policy knobs: while every axis sits at its fault-free default the
    # knob fields stay None and no fault ops are traced; any non-default
    # value activates them for the whole grid (a traced rate of exactly 0.0
    # stays bit-identical to the fault-free program, so mixed grids are
    # safe).
    prog_fail_rate: tuple[float, ...] = (0.0,)
    erase_fail_rate: tuple[float, ...] = (0.0,)
    max_read_retries: tuple[int, ...] = (-1,)
    fault_seed: tuple[int, ...] = (0,)
    # wear-coupled reliability axes (DESIGN.md §2D, wear-correlated): ride
    # the fault-knob activation above; their fault-free defaults (rate 0.0,
    # slope 0.0, rebuild off, unbounded spares) trace bit-identically to the
    # flat-rate program, so mixed grids stay safe
    read_fail_rate: tuple[float, ...] = (0.0,)
    fault_wear_slope: tuple[float, ...] = (0.0,)
    parity_rebuild: tuple[bool, ...] = (False,)
    spare_blocks: tuple[int, ...] = (-1,)
    # GC victim-objective axis (DESIGN.md §2E), batched through
    # RunKnobs.gc_objective as integer codes: while the axis sits at its
    # default the knob stays None (no formula-select traced); a mixed axis
    # runs both objectives in one compiled program, with code 0 (min_valid)
    # pinned bit-identical to the knob-free trace.
    gc_objective: tuple[str, ...] = ("min_valid",)
    # forwarded to the scenario builder (e.g. {"theta": 1.2}); tuple-of-items
    # so the spec stays hashable
    scenario_kw: tuple[tuple[str, object], ...] = ()
    base: geometry.SimConfig = field(default_factory=geometry.SimConfig)

    def n_runs(self) -> int:
        return (len(self.policies) * len(self.initial_pe) * len(self.seeds)
                * len(self.r1) * len(self.r2_override)
                * len(self.arrival_scale) * len(self.prog_fail_rate)
                * len(self.erase_fail_rate) * len(self.max_read_retries)
                * len(self.fault_seed) * len(self.read_fail_rate)
                * len(self.fault_wear_slope) * len(self.parity_rebuild)
                * len(self.spare_blocks) * len(self.gc_objective))

    def faults_on(self) -> bool:
        """Any fault axis off its fault-free default -> the grid batches
        fault knobs through RunKnobs (see ``faults.params_for``)."""
        return (self.prog_fail_rate != (0.0,)
                or self.erase_fail_rate != (0.0,)
                or self.max_read_retries != (-1,)
                or self.fault_seed != (0,)
                or self.read_fail_rate != (0.0,)
                or self.fault_wear_slope != (0.0,)
                or self.parity_rebuild != (False,)
                or self.spare_blocks != (-1,))


@dataclass(frozen=True)
class RunSpec:
    """One point of the grid."""

    scenario: str
    policy: int
    initial_pe: int
    seed: int
    r1: int
    r2_override: int
    arrival_scale: float = 1.0
    prog_fail_rate: float = 0.0
    erase_fail_rate: float = 0.0
    max_read_retries: int = -1
    fault_seed: int = 0
    read_fail_rate: float = 0.0
    fault_wear_slope: float = 0.0
    parity_rebuild: bool = False
    spare_blocks: int = -1
    gc_objective: str = "min_valid"

    def tag(self) -> str:
        parts = [
            self.scenario,
            geometry.POLICY_NAMES[self.policy],
            f"pe{self.initial_pe}",
            f"seed{self.seed}",
        ]
        if self.r1 != 1:
            parts.append(f"r1_{self.r1}")
        if self.r2_override >= 0:
            parts.append(f"r2_{self.r2_override}")
        if self.arrival_scale != 1.0:
            parts.append(f"load{self.arrival_scale:g}")
        if self.prog_fail_rate != 0.0:
            parts.append(f"pfail{self.prog_fail_rate:g}")
        if self.erase_fail_rate != 0.0:
            parts.append(f"efail{self.erase_fail_rate:g}")
        if self.max_read_retries >= 0:
            parts.append(f"mrr{self.max_read_retries}")
        if self.fault_seed != 0:
            parts.append(f"fseed{self.fault_seed}")
        if self.read_fail_rate != 0.0:
            parts.append(f"rfail{self.read_fail_rate:g}")
        if self.fault_wear_slope != 0.0:
            parts.append(f"wear{self.fault_wear_slope:g}")
        if self.parity_rebuild:
            parts.append("parity")
        if self.spare_blocks >= 0:
            parts.append(f"spares{self.spare_blocks}")
        if self.gc_objective != "min_valid":
            parts.append(f"gc_{self.gc_objective}")
        return "_".join(parts)


def expand(spec: SweepSpec) -> list[RunSpec]:
    return [
        RunSpec(spec.scenario, pol, pe, seed, r1, r2, scale, pf, ef, mrr, fs,
                rf, ws, pr, sb, gco)
        for pol, pe, seed, r1, r2, scale, pf, ef, mrr, fs, rf, ws, pr, sb, gco
        in itertools.product(
            spec.policies, spec.initial_pe, spec.seeds, spec.r1,
            spec.r2_override, spec.arrival_scale, spec.prog_fail_rate,
            spec.erase_fail_rate, spec.max_read_retries, spec.fault_seed,
            spec.read_fail_rate, spec.fault_wear_slope, spec.parity_rebuild,
            spec.spare_blocks, spec.gc_objective
        )
    ]


def _run_batch(cfg: geometry.SimConfig, has_writes: bool, lpns, ops,
               knobs: policies.RunKnobs, arrival_ms=None):
    """Vmapped body shared by both executors; everything dynamic rides the
    stacked run axis.

    ``lpns``/``ops``: (R, n_chunks, chunk); ``knobs``: (R,) fields;
    ``arrival_ms``: (R, n_chunks, chunk) f32 or None (closed loop). Returns
    the stacked final state pytree (leading run axis on every leaf).
    """

    def one(lpns_i, ops_i, knobs_i, arr_i=None):
        s0 = st.init_state(cfg, initial_pe=knobs_i.initial_pe,
                           spare_blocks=knobs_i.spare_blocks)

        def body(s, x):
            return engine.step_chunk(s, x, cfg, has_writes, knobs_i)

        xs = (lpns_i, ops_i) if arr_i is None else (lpns_i, ops_i, arr_i)
        s, _ = lax.scan(body, s0, xs)
        return s

    if arrival_ms is None:
        return jax.vmap(one)(lpns, ops, knobs)
    return jax.vmap(one)(lpns, ops, knobs, arrival_ms)


@partial(jax.jit, static_argnums=(0, 3))
def _sweep_jit(cfg: geometry.SimConfig, lpns, ops, has_writes: bool,
               knobs: policies.RunKnobs, arrival_ms=None):
    """Single-device executor: the whole run axis on one ``jax.vmap``."""
    return _run_batch(cfg, has_writes, lpns, ops, knobs, arrival_ms)


@partial(jax.jit, static_argnums=(0, 3, 6))
def _sweep_sharded_jit(cfg: geometry.SimConfig, lpns, ops, has_writes: bool,
                       knobs: policies.RunKnobs, arrival_ms, mesh: Mesh):
    """Sharded executor: the run axis (a multiple of the mesh size — the
    caller pads) is split across ``mesh``'s devices via ``shard_map``; each
    device runs the identical vmapped program on its local runs, so results
    are bitwise identical to the single-device path. No collectives — runs
    are independent, making the shard axis embarrassingly parallel."""
    spec = P(_MESH_AXIS)
    # check_rep=False: nothing here is replicated and there are no
    # collectives, but the checker mis-types the engine's pressure-gated
    # lax.cond branches (jax 0.4.x) — disabling it changes nothing else
    if arrival_ms is None:
        fn = shard_map(
            lambda l, o, k: _run_batch(cfg, has_writes, l, o, k),
            mesh=mesh, in_specs=(spec, spec, spec), out_specs=spec,
            check_rep=False,
        )
        return fn(lpns, ops, knobs)
    fn = shard_map(
        lambda l, o, k, a: _run_batch(cfg, has_writes, l, o, k, a),
        mesh=mesh, in_specs=(spec, spec, spec, spec), out_specs=spec,
        check_rep=False,
    )
    return fn(lpns, ops, knobs, arrival_ms)


_MESH_AXIS = "runs"


def resolve_devices(devices):
    """Normalize the ``devices`` argument to a tuple of jax devices (or None
    for the single-device vmap path). Accepts an int count, ``"all"``, an
    explicit device sequence, or a numeric string — so CLI entry points can
    forward their ``--devices`` argument verbatim (and validate it early via
    this function without paying for trace building first)."""
    if devices is None:
        return None
    if devices == "all":
        return tuple(jax.devices())
    if isinstance(devices, str):
        devices = int(devices)
    if isinstance(devices, int):
        avail = jax.devices()
        if devices < 1:
            raise ValueError(f"devices must be >= 1, got {devices}")
        if devices > len(avail):
            # clamp-and-warn rather than abort: an over-asked sweep on a
            # smaller host still runs (bit-identical results, just less
            # parallel), which is what a batch harness wants
            warnings.warn(
                f"requested {devices} devices but only {len(avail)} visible; "
                f"clamping to {len(avail)} "
                f"(hint: XLA_FLAGS=--xla_force_host_platform_device_count=N "
                f"fakes N host devices)",
                stacklevel=2,
            )
            devices = len(avail)
        return tuple(avail[:devices])
    return tuple(devices)


def _take_run(stacked, i: int):
    return jax.tree_util.tree_map(lambda x: x[i], stacked)


def assert_results_identical(a, b):
    """Assert two ``run_sweep`` result lists are the same runs in the same
    order with every summarize value exactly equal — the sharded-executor
    guarantee. One checker shared by the equivalence tests and the scaling
    benchmark's self-check; raises explicitly (not bare ``assert``) so the
    benchmark keeps its guarantee under ``python -O``."""
    if len(a) != len(b):
        raise AssertionError(f"{len(a)} runs vs {len(b)}")
    for ra, rb in zip(a, b):
        if ra["run"] != rb["run"]:
            raise AssertionError(f"run order diverged: {ra['run']} vs {rb['run']}")
        if ra.keys() != rb.keys():
            raise AssertionError(f"metric keys diverged for {ra['run']['tag']}")
        for k in ra:
            if k != "run":
                np.testing.assert_array_equal(
                    np.asarray(ra[k]), np.asarray(rb[k]),
                    err_msg=f"{ra['run']['tag']}/{k}",
                )


def _group_ckpt_path(resume_dir, spec: SweepSpec, pol: int) -> Path:
    return (Path(resume_dir)
            / f"ckpt_{spec.scenario}_{geometry.POLICY_NAMES[pol]}.json")


def _load_group_checkpoint(path: Path, expect_tags, spec: SweepSpec,
                           threads: int):
    """Completed-group results from a prior run, or None when absent/stale.

    A checkpoint is only honored when its run tags (which encode every knob
    of every run in order), request count and thread model match — anything
    else is a different experiment and must re-run."""
    try:
        doc = json.loads(path.read_text())
    except (OSError, ValueError):
        return None
    if (doc.get("tags") != expect_tags
            or doc.get("n_requests") != spec.n_requests
            or doc.get("threads") != threads):
        return None
    return doc["results"]


def _write_group_checkpoint(path: Path, expect_tags, spec: SweepSpec,
                            threads: int, group_results) -> None:
    """Persist one completed policy group. Write-then-rename so a kill
    mid-write never leaves a truncated checkpoint; JSON float round-trips
    are exact in Python 3, so resumed results satisfy
    :func:`assert_results_identical` against an uninterrupted run."""
    path.parent.mkdir(parents=True, exist_ok=True)
    doc = dict(tags=expect_tags, n_requests=spec.n_requests, threads=threads,
               results=group_results)
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_text(json.dumps(doc))
    tmp.replace(path)


def _retry_delays(max_retries: int, backoff_s: float):
    return [backoff_s * (2 ** i) for i in range(max_retries)]


def run_sweep(spec: SweepSpec, threads: int = 4, verbose: bool = False,
              devices=None, resume_dir=None, max_retries: int = 2,
              retry_backoff_s: float = 0.5):
    """Execute the grid. Returns one result dict per run: everything from
    ``engine.summarize`` (mean + p50/p95/p99/p999 read latency, IOPS,
    capacity, ...) plus the run's metadata under ``"run"``.

    ``devices`` selects the executor: ``None`` keeps the whole run axis on
    one device (``jax.vmap``); an int N / ``"all"`` / a device sequence
    shards the run axis across those devices (identical results — see
    :func:`_sweep_sharded_jit`). Every policy group is dispatched before any
    result is fetched, so compile and execution overlap across groups.

    Robustness (DESIGN.md §2D): ``resume_dir`` checkpoints each completed
    policy group to disk and deterministically resumes from matching
    checkpoints on a rerun — a killed sweep repeats only the unfinished
    groups and the merged results are identical to an uninterrupted run.
    Device dispatch/fetch failures are retried ``max_retries`` times with
    exponential backoff (``retry_backoff_s`` doubling per attempt); a group
    still failing after that does not lose the rest of the grid — every
    other group completes (and checkpoints) before a ``RuntimeError`` names
    the poisoned groups.
    """
    devs = resolve_devices(devices)  # validate before the trace-build cost
    runs = expand(spec)
    kw = dict(spec.scenario_kw)
    faults_on = spec.faults_on()
    if len(spec.seeds) > 1 and registry.is_seed_invariant(spec.scenario):
        warnings.warn(
            f"scenario {spec.scenario!r} is deterministic w.r.t. seed; "
            f"{len(spec.seeds)} seeds will produce identical runs",
            stacklevel=2,
        )

    # traces depend only on (scenario, seed): build each once, share across
    # policies/knobs
    traces: dict[int, dict] = {}
    for seed in spec.seeds:
        traces[seed] = registry.build(
            spec.scenario, spec.base, spec.n_requests, seed=seed, **kw
        )
    has_writes = bool(any((t["op"] == engine.OP_WRITE).any() for t in traces.values()))
    open_loop = all("arrival_ms" in t for t in traces.values())
    if spec.arrival_scale != (1.0,) and not open_loop:
        warnings.warn(
            f"scenario {spec.scenario!r} has no arrival timestamps; the "
            f"arrival_scale axis {spec.arrival_scale} has no effect on "
            "closed-loop runs",
            stacklevel=2,
        )

    mesh = Mesh(np.asarray(devs), (_MESH_AXIS,)) if devs is not None else None
    run_sharding = (
        NamedSharding(mesh, P(_MESH_AXIS)) if mesh is not None else None
    )

    # ---- phase 1: dispatch every policy group (nothing blocks on results;
    # group k+1's trace/compile overlaps group k's execution) ----
    pending = []
    for pol in spec.policies:  # static axis -> one compile each
        group = [r for r in runs if r.policy == pol]
        cfg = replace(spec.base, policy=pol)
        expect_tags = [r.tag() for r in group]
        if resume_dir is not None:
            cached = _load_group_checkpoint(
                _group_ckpt_path(resume_dir, spec, pol), expect_tags, spec,
                threads,
            )
            if cached is not None:
                if verbose:
                    print(f"# sweep group policy={geometry.POLICY_NAMES[pol]}"
                          f": {len(group)} runs resumed from checkpoint",
                          flush=True)
                pending.append((group, cfg, None, None, cached))
                continue

        def _dispatch(group=group, cfg=cfg, pol=pol):
            # pad uneven grids (and grids smaller than the device count)
            # with dummy replicas of the last run so the run axis divides
            # the mesh; the pads are dropped on the host below, never
            # summarized
            n_pad = (-len(group)) % len(devs) if devs is not None else 0
            padded = group + [group[-1]] * n_pad
            # stacked on the host (numpy): the vmap path lets jit move them
            # to the default device as before, the sharded path transfers
            # each array exactly once, straight to its run-sharded layout
            lpns = np.stack([np.asarray(traces[r.seed]["lpn"], np.int32) for r in padded])
            ops = np.stack([np.asarray(traces[r.seed]["op"], np.int32) for r in padded])
            arr = (
                np.stack([np.asarray(traces[r.seed]["arrival_ms"], np.float32)
                          for r in padded])
                if open_loop else None
            )
            knobs = policies.RunKnobs(
                r1=np.asarray([r.r1 for r in padded], np.int32),
                r2_override=np.asarray([r.r2_override for r in padded], np.int32),
                initial_pe=np.asarray([r.initial_pe for r in padded], np.int32),
                arrival_scale=(
                    np.asarray([r.arrival_scale for r in padded], np.float32)
                    if open_loop else None
                ),
                prog_fail_rate=(
                    np.asarray([r.prog_fail_rate for r in padded], np.float32)
                    if faults_on else None
                ),
                erase_fail_rate=(
                    np.asarray([r.erase_fail_rate for r in padded], np.float32)
                    if faults_on else None
                ),
                max_read_retries=(
                    np.asarray([r.max_read_retries for r in padded], np.int32)
                    if faults_on else None
                ),
                fault_seed=(
                    np.asarray([r.fault_seed for r in padded], np.int32)
                    if faults_on else None
                ),
                read_fail_rate=(
                    np.asarray([r.read_fail_rate for r in padded], np.float32)
                    if faults_on else None
                ),
                fault_wear_slope=(
                    np.asarray([r.fault_wear_slope for r in padded],
                               np.float32)
                    if faults_on else None
                ),
                parity_rebuild=(
                    np.asarray([int(r.parity_rebuild) for r in padded],
                               np.int32)
                    if faults_on else None
                ),
                spare_blocks=(
                    np.asarray([r.spare_blocks for r in padded], np.int32)
                    if faults_on else None
                ),
                gc_objective=(
                    np.asarray(
                        [reclaim.GC_OBJECTIVE_CODES[r.gc_objective]
                         for r in padded], np.int32)
                    if spec.gc_objective != ("min_valid",) else None
                ),
            )
            if verbose:
                where = (f"sharded over {len(devs)} devices"
                         f" (+{n_pad} pad)" if devs is not None else "one device")
                print(f"# sweep group policy={geometry.POLICY_NAMES[pol]}: "
                      f"{len(group)} runs in one jit, {where}", flush=True)
            if mesh is None:
                return _sweep_jit(cfg, lpns, ops, has_writes, knobs, arr)
            place = lambda x: jax.device_put(x, run_sharding)  # noqa: E731
            lpns, ops = place(lpns), place(ops)
            arr = place(arr) if arr is not None else None
            knobs = jax.tree_util.tree_map(place, knobs)
            return _sweep_sharded_jit(cfg, lpns, ops, has_writes, knobs,
                                      arr, mesh)

        try:
            states = _dispatch()
        except Exception as e:  # retried with backoff in phase 2
            warnings.warn(
                f"dispatch of sweep group {geometry.POLICY_NAMES[pol]!r} "
                f"failed ({e!r}); will retry",
                stacklevel=2,
            )
            states = None
        pending.append((group, cfg, states, _dispatch, None))

    # ---- phase 2: one batched device->host transfer per group, then
    # summarize on numpy leaves off the dispatch critical path ----
    results = []
    failed = []
    for group, cfg, states, redispatch, cached in pending:
        if cached is not None:
            results.extend(cached)
            continue
        name = geometry.POLICY_NAMES[group[0].policy]
        host = None
        last_err = None
        delays = _retry_delays(max_retries, retry_backoff_s)
        for attempt in range(max_retries + 1):
            try:
                if states is None:  # prior dispatch/fetch failed -> redo
                    states = redispatch()
                host = jax.device_get(states)  # blocks on this group only
                break
            except Exception as e:  # one poisoned group must not lose the grid
                last_err = e
                states = None
                if attempt < max_retries:
                    warnings.warn(
                        f"sweep group {name!r} failed ({e!r}); retry "
                        f"{attempt + 1}/{max_retries} in "
                        f"{delays[attempt]:.1f}s",
                        stacklevel=2,
                    )
                    time.sleep(delays[attempt])
        if host is None:
            failed.append((name, last_err))
            continue
        group_results = []
        for i, r in enumerate(group):  # pads (indices >= len(group)) dropped
            m = engine.summarize(_take_run(host, i), cfg, threads=threads)
            m["run"] = dict(
                scenario=r.scenario,
                policy=geometry.POLICY_NAMES[r.policy],
                initial_pe=r.initial_pe,
                seed=r.seed,
                r1=r.r1,
                r2_override=r.r2_override,
                arrival_scale=r.arrival_scale,
                prog_fail_rate=r.prog_fail_rate,
                erase_fail_rate=r.erase_fail_rate,
                max_read_retries=r.max_read_retries,
                fault_seed=r.fault_seed,
                read_fail_rate=r.read_fail_rate,
                fault_wear_slope=r.fault_wear_slope,
                parity_rebuild=r.parity_rebuild,
                spare_blocks=r.spare_blocks,
                gc_objective=r.gc_objective,
                n_requests=spec.n_requests,
                tag=r.tag(),
            )
            group_results.append(m)
        if resume_dir is not None:
            _write_group_checkpoint(
                _group_ckpt_path(resume_dir, spec, group[0].policy),
                [r.tag() for r in group], spec, threads, group_results,
            )
        results.extend(group_results)
    if failed:
        names = ", ".join(n for n, _ in failed)
        hint = (
            "completed groups were checkpointed to resume_dir and are "
            "reused on rerun" if resume_dir is not None else
            "pass resume_dir= to checkpoint completed groups across reruns"
        )
        raise RuntimeError(
            f"sweep group(s) failed after {max_retries} retries: {names} "
            f"({hint})"
        ) from failed[0][1]
    return results


# --------------------------- result artifacts ------------------------------

# Scalar metric names + units come from the single schema registry
# (ssdsim/metrics_schema.py); the name is kept for backward compatibility.
_ROW_UNITS = metrics_schema.row_units()


def result_rows(res: dict, prefix: str = "sweep"):
    """Flatten one run result into harness-style (name, value, unit) rows."""
    tag = res["run"]["tag"]
    rows = [
        (f"{prefix}/{tag}/{k}", float(res[k]), u)
        for k, u in _ROW_UNITS.items()
        if k in res
    ]
    # per-mode observability readout (present at obs_level="full"):
    # retry share of each mode's p99 tail mass (DESIGN.md §7.4)
    if "tail_retry_share" in res:
        from repro.core import modes
        rows += [
            (f"{prefix}/{tag}/tail_retry_share_{name.lower()}",
             float(v), "fraction")
            for name, v in zip(modes.MODE_NAMES, res["tail_retry_share"])
        ]
    return rows


def _json_safe(v):
    """Summarize values are floats, nested lists, or ndarrays — normalize
    all three to JSON-native types."""
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, (list, tuple)):
        return [_json_safe(x) for x in v]
    return float(v)


def write_artifacts(results, out_dir, prefix: str = "sweep") -> list[Path]:
    """One ``BENCH_<tag>.json`` per run, mirroring the harness CSV rows so
    artifacts and stdout stay diffable against each other."""
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    paths = []
    for res in results:
        doc = {
            "name": f"{prefix}/{res['run']['tag']}",
            "run": res["run"],
            "metrics": {
                k: _json_safe(v) for k, v in res.items() if k != "run"
            },
            "rows": [list(r) for r in result_rows(res, prefix)],
        }
        p = out / f"BENCH_{prefix}_{res['run']['tag']}.json"
        p.write_text(json.dumps(doc, indent=1, sort_keys=True))
        paths.append(p)
    return paths
