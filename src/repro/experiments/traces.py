"""Block-trace replay (DESIGN.md §7.2).

Replays MSR-Cambridge-style block traces through the simulator. The MSR
format (SNIA IOTTA) is a headerless CSV:

    Timestamp,Hostname,DiskNumber,Type,Offset,Size,ResponseTime

with ``Timestamp`` in Windows filetime ticks, ``Type`` in {Read, Write},
``Offset``/``Size`` in bytes and ``ResponseTime`` in microseconds. Each I/O
is expanded into per-page requests (16 KiB simulator pages) and the trace's
byte-address footprint is wrapped onto the simulated LPN space with relative
locality preserved, so hot ranges in the trace stay hot ranges on the
device.

A small bundled sample (``data/msr_sample.csv``, same column layout) keeps
the subsystem testable offline; drop a real ``*.csv`` from the MSR corpus
next to it (or pass an absolute path) to replay production traces.
"""

from __future__ import annotations

import csv
from pathlib import Path

import numpy as np

from repro.experiments.registry import register
from repro.ssdsim import geometry, workload
from repro.ssdsim.engine import OP_READ, OP_WRITE

DATA_DIR = Path(__file__).parent / "data"
SAMPLE_TRACE = DATA_DIR / "msr_sample.csv"

_READ_ALIASES = {"read", "r", "rs"}
_WRITE_ALIASES = {"write", "w", "ws"}


def parse_msr_csv(path) -> dict[str, np.ndarray]:
    """Parse an MSR-format CSV into ``{timestamp, op, offset, size}`` arrays.

    ``op`` is OP_READ/OP_WRITE, ``offset``/``size`` are int64 bytes. A
    leading header row (non-numeric timestamp) is tolerated and skipped, as
    are malformed/empty lines — real MSR files occasionally contain both.
    """
    ts, op, off, sz = [], [], [], []
    with open(path, newline="") as f:
        for row in csv.reader(f):
            if len(row) < 6:
                continue
            try:
                t = int(row[0])
                o = int(row[4])
                s = int(row[5])
            except ValueError:
                continue  # header or malformed line
            kind = row[3].strip().lower()
            if kind in _READ_ALIASES:
                op.append(OP_READ)
            elif kind in _WRITE_ALIASES:
                op.append(OP_WRITE)
            else:
                continue
            ts.append(t)
            off.append(o)
            sz.append(s)
    if not ts:
        raise ValueError(f"no parseable records in trace {path}")
    return {
        "timestamp": np.asarray(ts, np.int64),
        "op": np.asarray(op, np.int32),
        "offset": np.asarray(off, np.int64),
        "size": np.asarray(sz, np.int64),
    }


def records_to_page_requests(cfg: geometry.SimConfig, rec: dict[str, np.ndarray]):
    """Expand byte-granular I/Os into per-page (lpn, op, arrival_ms) streams.

    Each I/O touches ``ceil(size / page_bytes)`` consecutive pages starting
    at ``offset // page_bytes``; every page of an I/O inherits the I/O's
    arrival time. The trace's page-address range is shifted to start at 0
    and wrapped modulo ``n_logical``: relative locality (and thus
    block-level read-disturb concentration) survives the remap even when the
    traced volume is far larger than the simulated device. Arrival times are
    Windows-filetime ticks (100 ns) rebased to ms from the first record.
    """
    pb = cfg.page_bytes
    first = rec["offset"] // pb
    n_pages = np.maximum(-(-(rec["offset"] % pb + rec["size"]) // pb), 1)
    base = int(first.min())

    lpn = np.repeat(first - base, n_pages)
    # per-request offsets 0..n_pages-1 within each I/O
    cum = np.cumsum(n_pages)
    idx = np.arange(cum[-1], dtype=np.int64)
    idx -= np.repeat(cum - n_pages, n_pages)
    lpn = (lpn + idx) % cfg.n_logical
    op = np.repeat(rec["op"], n_pages)
    ts = rec["timestamp"]
    arrival_ms = np.repeat((ts - ts.min()) / 1e4, n_pages).astype(np.float64)
    return lpn.astype(np.int32), op.astype(np.int32), arrival_ms


def replay_trace(cfg: geometry.SimConfig, path, n_requests: int | None = None,
                 arrivals: bool = True, time_scale: float = 1.0):
    """Full pipeline: CSV -> page requests -> packed engine trace.

    ``n_requests`` truncates (or cycles, if the trace is shorter) the
    request stream so sweep groups can share one static trace shape; cycled
    repetitions are shifted by the trace duration so arrival times stay
    nondecreasing. ``arrivals=False`` drops the timestamp column and replays
    the trace closed-loop (the pre-arrival-model behavior);
    ``time_scale > 1`` compresses the recorded timeline, raising the offered
    load (the sweep runner's ``arrival_scale`` knob does the same per run
    without rebuilding the trace).
    """
    lpn, op, arr = records_to_page_requests(cfg, parse_msr_csv(path))
    if n_requests is not None:
        if len(lpn) < n_requests:  # cycle the trace to fill the budget
            reps = -(-n_requests // len(lpn))
            span = arr[-1] + (arr[-1] - arr[0]) / max(len(arr) - 1, 1)
            arr = np.concatenate([arr + r * span for r in range(reps)])
            lpn = np.tile(lpn, reps)
            op = np.tile(op, reps)
        lpn, op, arr = lpn[:n_requests], op[:n_requests], arr[:n_requests]
    return workload._pack(cfg, lpn, op, arr / time_scale if arrivals else None)


@register("msr_sample", seed_invariant=True)
def msr_sample(cfg: geometry.SimConfig, n_requests: int, seed: int = 0,
               path=None, arrivals: bool = True, time_scale: float = 1.0):
    """Replay of the bundled MSR-style sample trace (seed is unused; trace
    replay is deterministic by construction). Replays open-loop against the
    CSV's timestamp column by default; ``arrivals=False`` restores the
    closed-loop replay."""
    return replay_trace(cfg, path or SAMPLE_TRACE, n_requests=n_requests,
                        arrivals=arrivals, time_scale=time_scale)
