"""Pre-jax host-device helpers.

This module must import nothing that touches jax: its whole point is to
mutate ``XLA_FLAGS`` *before* the first jax import, which is the only time
``--xla_force_host_platform_device_count`` is honored. Shared by the CLI
entry points that offer ``--fake-devices`` (``benchmarks/sweep_bench.py``,
``examples/sweep_experiments.py``).
"""

from __future__ import annotations

import os
import warnings


def fake_host_devices(n: int | None) -> None:
    """Make the CPU backend present ``n`` host devices (no-op for falsy
    ``n``). Call before anything imports jax; appending wins over an earlier
    ``--xla_force_host_platform_device_count`` in ``XLA_FLAGS`` because XLA
    resolves duplicate flags last-wins.

    Asking for more fake devices than the host has cores oversubscribes the
    CPU (XLA pins one thread pool per device) and can look like a hang on
    small runners, so the count is clamped to ``os.cpu_count()`` with a
    warning instead of being passed through silently."""
    if not n:
        return
    n = int(n)
    if n < 1:
        raise ValueError(f"fake device count must be >= 1, got {n}")
    cores = os.cpu_count() or 1
    if n > cores:
        warnings.warn(
            f"requested {n} fake host devices but the host has {cores} "
            f"cores; clamping to {cores} (oversubscribed XLA host devices "
            f"thrash rather than parallelize)",
            stacklevel=2,
        )
        n = cores
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n}"
    ).strip()
