"""Pre-jax host-device helpers.

This module must import nothing that touches jax: its whole point is to
mutate ``XLA_FLAGS`` *before* the first jax import, which is the only time
``--xla_force_host_platform_device_count`` is honored. Shared by the CLI
entry points that offer ``--fake-devices`` (``benchmarks/sweep_bench.py``,
``examples/sweep_experiments.py``).
"""

from __future__ import annotations

import os


def fake_host_devices(n: int | None) -> None:
    """Make the CPU backend present ``n`` host devices (no-op for falsy
    ``n``). Call before anything imports jax; appending wins over an earlier
    ``--xla_force_host_platform_device_count`` in ``XLA_FLAGS`` because XLA
    resolves duplicate flags last-wins."""
    if n:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={int(n)}"
        ).strip()
