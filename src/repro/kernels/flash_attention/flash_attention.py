"""Pallas TPU flash-attention forward kernel (training/prefill hot spot).

Grid: (B*H, Sq/BQ, Sk/BK) with the KV axis innermost (sequential on TPU),
online-softmax state carried in VMEM scratch across KV blocks. Block shapes
default to (128, 128) — MXU-aligned. GQA is handled in the KV index_map
(query head h reads KV head h // group).

VMEM working set per program:
  q (BQ, D) + k (BK, D) + v (BK, D) + acc (BQ, D) f32 + p (BQ, BK) f32
  = 128*128*(2+2+2+4) + 128*128*4 B ~ 0.26 MiB at D=128 — comfortably
  within the ~16 MiB/core budget, leaving headroom for double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                scale: float, causal: bool, bq: int, bk: int, n_k: int, sk_valid: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale  # (BQ, D)
    k = k_ref[0].astype(jnp.float32)  # (BK, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (BQ, BK)

    k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < sk_valid  # tail padding
    if causal:
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        mask &= q_pos >= k_pos
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
        p.astype(v_ref.dtype), v_ref[0]
    ).astype(jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, sk_valid: int | None = None,
                        causal: bool = True, block_q: int = 128,
                        block_k: int = 128, interpret: bool = True):
    """q: (BH, Sq, D); k, v: (BH_kv, Sk, D) with BH = B*H, BH_kv = B*Hk and
    the GQA group g = BH // BH_kv applied per batch entry. Sq, Sk must be
    pre-padded to block multiples by the ops wrapper; ``sk_valid`` masking
    is folded into the kernel via the true sk passed in.
    """
    bh, sq, d = q.shape
    bh_kv, sk, _ = k.shape
    g = bh // bh_kv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    n_q = sq // bq
    n_k = sk // bk

    kernel = functools.partial(
        _fwd_kernel, scale=d**-0.5, causal=causal, bq=bq, bk=bk, n_k=n_k,
        sk_valid=sk_valid if sk_valid is not None else sk,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, qi, kj: (h, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda h, qi, kj, g_=g: (h // g_, kj, 0)),
            pl.BlockSpec((1, bk, d), lambda h, qi, kj, g_=g: (h // g_, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, qi, kj: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
