"""jit'd public wrapper: (B, S, H, D) layout, GQA, padding, custom VJP.

The backward pass uses the standard flash recompute-from-(o, lse) trick via
jax.checkpoint over the reference — the forward kernel is the perf-critical
path (decode/prefill); training grads fall back to the blockwise-jnp path
which XLA fuses well on TPU.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_fwd
from repro.models import attention as jnp_attn


def _pad_to(x, axis, mult):
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x, s
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg), s


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: (B, Sq, H, D); k, v: (B, Sk, Hk, D) -> (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    _, sk, hk, _ = k.shape

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hk, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hk, sk, d)

    qf, sq0 = _pad_to(qf, 1, block_q)
    kf, sk0 = _pad_to(kf, 1, block_k)
    vf, _ = _pad_to(vf, 1, block_k)

    o = flash_attention_fwd(qf, kf, vf, sk_valid=sk0, causal=causal,
                            block_q=block_q, block_k=block_k, interpret=interpret)
    o = o[:, :sq0].reshape(b, h, sq0, d).transpose(0, 2, 1, 3)
    return o
