"""Pure-jnp oracle for the flash-attention kernel."""

from repro.models.attention import reference_attention


def flash_attention_ref(q, k, v, *, causal: bool = True):
    return reference_attention(q, k, v, causal=causal)
