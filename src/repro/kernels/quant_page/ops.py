"""jit'd wrapper for the page-quantization migration kernel."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.quant_page.quant_page import quantize_pages


@partial(jax.jit, static_argnames=("tier", "interpret"))
def quant_pages(x, *, tier: int, interpret: bool = True):
    q, s, e = quantize_pages(x, tier=tier, interpret=interpret)
    return q, s, e[:, 0]
