"""Pallas page-(re)quantization kernel — the RARO migration hot path.

Grid over pages; each program loads one bf16 page (P, Hk, D) from the
source view, computes per-head symmetric scales, emits the quantized page
(int8, or int4 packed 2-per-byte) + scales + the relative RMS error of the
page (the controller's RBER-analogue measurement, so migration cost and
error tracking come from the same pass).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import modes

_QMAX = {modes.TIER_INT8: 127.0, modes.TIER_INT4: 7.0}


def _quant_kernel(x_ref, q_ref, s_ref, e_ref, *, tier: int, d: int):
    x = x_ref[0].astype(jnp.float32)  # (P, Hk, D)
    qmax = _QMAX[tier]
    amax = jnp.max(jnp.abs(x), axis=(0, 2))  # (Hk,)
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(x / scale[None, :, None]), -qmax, qmax)
    err_num = jnp.sqrt(jnp.mean((x - q * scale[None, :, None]) ** 2))
    err_den = jnp.sqrt(jnp.mean(x * x)) + 1e-8
    if tier == modes.TIER_INT8:
        q_ref[0] = q.astype(jnp.int8)
    else:
        qi = q.astype(jnp.int8)
        lo = qi[..., 0::2] & 0x0F
        hi = (qi[..., 1::2] & 0x0F) << 4
        q_ref[0] = (lo | hi).astype(jnp.int8)
    s_ref[0] = scale.astype(s_ref.dtype)
    e_ref[0, 0] = (err_num / err_den).astype(e_ref.dtype)


def quantize_pages(x, *, tier: int, interpret: bool = True):
    """x: (N, P, Hk, D) bf16/f32 pages -> (q, scales (N, Hk), err (N,)).

    q is (N, P, Hk, D) int8 for tier=int8 or (N, P, Hk, D//2) packed for
    tier=int4.
    """
    n, p, hk, d = x.shape
    dq = d if tier == modes.TIER_INT8 else d // 2
    kernel = functools.partial(_quant_kernel, tier=tier, d=d)
    return pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, p, hk, d), lambda i: (i, 0, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, p, hk, dq), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, hk), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, p, hk, dq), jnp.int8),
            jax.ShapeDtypeStruct((n, hk), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
