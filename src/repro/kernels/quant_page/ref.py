"""Pure-jnp oracle for the page-quantization kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import modes
from repro.kvcache import quant


def quant_pages_ref(x, *, tier: int):
    if tier == modes.TIER_INT8:
        q, s = quant.quantize_int8(x)
        xd = quant.dequantize_int8(q, s, jnp.float32)
    else:
        q, s = quant.quantize_int4(x)
        xd = quant.dequantize_int4(q, s, jnp.float32)
    x32 = x.astype(jnp.float32)
    err = jnp.sqrt(jnp.mean((x32 - xd) ** 2, axis=(1, 2, 3))) / (
        jnp.sqrt(jnp.mean(x32**2, axis=(1, 2, 3))) + 1e-8
    )
    return q, s, err
