"""jit'd wrapper: full tiered decode attention over a TieredKV cache.

Runs one Pallas partial per tier (+ a jnp partial over the bf16 write
buffer), then combines flash-decoding style. Also renormalizes the
per-page attention masses that feed the RARO controller.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import modes
from repro.kernels.tiered_attention.tiered_attention import NEG_INF, tiered_decode_partial
from repro.kvcache import paged


def _buffer_partial(q, buf_k, buf_v, n_valid):
    """Partial over the open-page write buffer. q: (B,H,D); buf: (B,P,Hk,D);
    n_valid: (B,) tokens currently in the buffer."""
    b, h, d = q.shape
    _, p, hk, _ = buf_k.shape
    g = h // hk
    qh = (q.astype(jnp.float32) * d**-0.5).reshape(b, hk, g, d)
    s = jnp.einsum("bhgd,bphd->bhgp", qh, buf_k.astype(jnp.float32))
    mask = jnp.arange(p)[None, :] < n_valid[:, None]
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    pr = jnp.exp(s - m[..., None])
    l = pr.sum(axis=-1)
    acc = jnp.einsum("bhgp,bphd->bhgd", pr, buf_v.astype(jnp.float32))
    return acc.reshape(b, h, d), m.reshape(b, h), l.reshape(b, h)


def combine_partials(parts):
    """parts: list of (acc (B,H,D), m (B,H), l (B,H)) -> (out, M, L)."""
    ms = jnp.stack([m for _, m, _ in parts])  # (T, B, H)
    M = ms.max(0)
    L = jnp.zeros_like(M)
    out = jnp.zeros_like(parts[0][0])
    for acc, m, l in parts:
        w = jnp.exp(m - M)
        L = L + l * w
        out = out + acc * w[..., None]
    return out / jnp.maximum(L, 1e-30)[..., None], M, L


@partial(jax.jit, static_argnames=("cfg", "interpret"))
def tiered_decode_attention(q, cache: paged.TieredKV, cfg: paged.CacheConfig,
                            *, interpret: bool = True):
    """q: (B, H, D) -> (out (B,H,D), page_mass (B, MaxP)).

    page_mass[b, j] = attention probability mass on logical page j (mean
    over heads) — the RARO hotness signal.
    """
    b, h, d = q.shape
    parts = []
    page_stats = []

    pools = {
        modes.TIER_BF16: (cache.k16, cache.v16,
                          jnp.ones(cache.sk8.shape[1:][:0] + (cache.k16.shape[0], cfg.n_kv_heads), jnp.float32),
                          jnp.ones((cache.k16.shape[0], cfg.n_kv_heads), jnp.float32)),
        modes.TIER_INT8: (cache.k8, cache.v8, cache.sk8, cache.sv8),
        modes.TIER_INT4: (cache.k4, cache.v4, cache.sk4, cache.sv4),
    }
    for tier, (kp, vp, sk, sv) in pools.items():
        slot_t = jnp.where(cache.tier == tier, cache.slot, -1)
        o, m, l, pp, pm = tiered_decode_partial(q, kp, vp, sk, sv, slot_t,
                                                tier=tier, interpret=interpret)
        parts.append((o, m, l))
        page_stats.append((pp, pm))

    n_buf = cache.seq_len % cfg.page_size
    parts.append(_buffer_partial(q, cache.buf_k, cache.buf_v, n_buf))

    out, M, L = combine_partials(parts)

    # exact per-page mass: pp * exp(pm - M) / L, mean over heads
    mass = jnp.zeros((b, cfg.max_pages), jnp.float32)
    for pp, pm in page_stats:
        w = pp * jnp.exp(pm - M[:, None, :])
        mass = mass + (w / jnp.maximum(L, 1e-30)[:, None, :]).mean(-1) * (pm > NEG_INF / 2).any(-1)
    return out.astype(q.dtype), mass
