"""Pure-jnp oracle for tiered paged-decode attention (gather + dense
softmax over the dequantized logical sequence + per-page masses)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kvcache import paged

NEG_INF = -1e30


def tiered_decode_attention_ref(q, cache: paged.TieredKV, cfg: paged.CacheConfig):
    """q: (B, H, D) -> (out (B,H,D) f32, page_mass (B, MaxP))."""
    b, h, d = q.shape
    p, mp, hk = cfg.page_size, cfg.max_pages, cfg.n_kv_heads
    g = h // hk

    K, V = paged.gather_kv(cache, cfg, jnp.float32)  # (B, MP, P, Hk, D)
    K = K.reshape(b, mp * p, hk, d)
    V = V.reshape(b, mp * p, hk, d)
    # append buffer tokens at their true positions
    K = jnp.concatenate([K, cache.buf_k.astype(jnp.float32)], axis=1)
    V = jnp.concatenate([V, cache.buf_v.astype(jnp.float32)], axis=1)

    pos = jnp.arange(mp * p)
    committed = (cache.tier >= 0)[:, :, None]  # (B, MP, 1)
    valid_pool = jnp.broadcast_to(committed, (b, mp, p)).reshape(b, mp * p)
    n_buf = cache.seq_len % p
    valid_buf = jnp.arange(p)[None, :] < n_buf[:, None]
    valid = jnp.concatenate([valid_pool, valid_buf], axis=1)  # (B, MP*P + P)

    qh = (q.astype(jnp.float32) * d**-0.5).reshape(b, hk, g, d)
    s = jnp.einsum("bhgd,bkhd->bhgk", qh, K)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = s.max(-1, keepdims=True)
    pr = jnp.exp(s - m)
    l = pr.sum(-1, keepdims=True)
    probs = pr / jnp.maximum(l, 1e-30)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs, V).reshape(b, h, d)

    mass = probs.mean(axis=(1, 2))[:, : mp * p].reshape(b, mp, p).sum(-1)
    return out, mass
