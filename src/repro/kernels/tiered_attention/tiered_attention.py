"""Pallas TPU tiered paged-decode attention kernel.

One kernel instance per RARO tier (the dtype/dequant path is static per
pool — the flash analogue of "all pages in a block share a mode"). For one
decode token per sequence:

  grid = (B, MaxPages); page slots come from the page table via SCALAR
  PREFETCH (pltpu.PrefetchScalarGridSpec) so the DMA of the right page is
  issued ahead of compute — the canonical TPU paged-attention pattern.

Outputs are flash-decoding partials (m, l, acc) per sequence — combined
across tiers + the bf16 write buffer by ops.combine_partials — plus the
per-page attention mass (sum of unnormalized exp scores, normalized by the
combiner), which is EXACTLY the hotness signal the RARO controller
consumes. The hotness statistics therefore cost zero extra passes.

VMEM per program: one page (P, Hk, D') + q (Hk*G, D) + partials —
P=64, Hk<=16, D=128 int4-packed = 64*16*64 B = 64 KiB; tiny.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import modes

NEG_INF = -1e30


def _dequant_block(kp, scale, tier: int):
    """kp: (P, Hk, D') int8/bf16 page block; scale: (Hk,) f32."""
    if tier == modes.TIER_BF16:
        return kp.astype(jnp.float32)
    if tier == modes.TIER_INT8:
        return kp.astype(jnp.float32) * scale[None, :, None]
    # packed int4: (P, Hk, D//2) -> (P, Hk, D)
    lo = ((kp & 0x0F) ^ 0x08) - 0x08
    hi = kp >> 4
    q = jnp.stack([lo, hi], axis=-1).reshape(kp.shape[0], kp.shape[1], -1)
    return q.astype(jnp.float32) * scale[None, :, None]


def _decode_kernel(tbl_ref, q_ref, kp_ref, vp_ref, sk_ref, sv_ref,
                   o_ref, m_ref, l_ref, pp_ref, pm_ref, acc_ref, mscr_ref, lscr_ref,
                   *, tier: int, n_pages: int, page: int, hk: int, g: int,
                   d: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        mscr_ref[...] = jnp.full_like(mscr_ref, NEG_INF)
        lscr_ref[...] = jnp.zeros_like(lscr_ref)

    valid = tbl_ref[b, j] >= 0

    @pl.when(valid)
    def _page():
        q = q_ref[0].astype(jnp.float32) * scale  # (Hk*G, D)
        k = _dequant_block(kp_ref[0], sk_ref[0], tier)  # (P, Hk, D)
        v = _dequant_block(vp_ref[0], sv_ref[0], tier)
        qh = q.reshape(hk, g, d)
        s = jnp.einsum("hgd,phd->hgp", qh, k)  # (Hk, G, P)
        m_prev = mscr_ref[...]  # (Hk, G)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        lscr_ref[...] = lscr_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum("hgp,phd->hgd", p, v)
        mscr_ref[...] = m_new
        # per-(page, head) exp-sum + the max it was computed against; the
        # combiner renormalizes exactly with the final (m, l).
        pp_ref[0, 0] = p.sum(axis=-1).reshape(hk * g).astype(pp_ref.dtype)
        pm_ref[0, 0] = m_new.reshape(hk * g).astype(pm_ref.dtype)

    @pl.when(~valid)
    def _skip():
        pp_ref[0, 0] = jnp.zeros_like(pp_ref[0, 0])
        pm_ref[0, 0] = jnp.full_like(pm_ref[0, 0], NEG_INF)

    @pl.when(j == n_pages - 1)
    def _finalize():
        o_ref[0] = acc_ref[...].reshape(hk * g, d).astype(o_ref.dtype)
        m_ref[0] = mscr_ref[...].reshape(hk * g).astype(m_ref.dtype)
        l_ref[0] = lscr_ref[...].reshape(hk * g).astype(l_ref.dtype)


def tiered_decode_partial(q, k_pool, v_pool, sk, sv, slot_table, *, tier: int,
                          interpret: bool = True):
    """Per-tier flash-decoding partials.

    q: (B, H, D) one token per sequence.
    k_pool/v_pool: (N, P, Hk, D') pages (D' = D, or D//2 when tier=int4).
    sk/sv: (N, Hk) f32 scales (ignored for bf16; pass ones).
    slot_table: (B, MaxP) int32 pool slots for THIS tier, -1 = not-this-tier.

    Returns (o (B,H,D) f32 unnormalized acc, m (B,H), l (B,H),
             page_p (B,MaxP,H) per-page exp-sums, page_m (B,MaxP,H) the max
             each was computed against) — combine with ops.combine_partials.
    """
    b, h, d = q.shape
    n, page, hk, dp = k_pool.shape
    g = h // hk
    mp = slot_table.shape[1]

    kernel = functools.partial(
        _decode_kernel, tier=tier, n_pages=mp, page=page, hk=hk, g=g, d=d,
        scale=d**-0.5,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, mp),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda b, j, t: (b, 0, 0)),
            pl.BlockSpec((1, page, hk, dp), lambda b, j, t: (jnp.maximum(t[b, j], 0), 0, 0, 0)),
            pl.BlockSpec((1, page, hk, dp), lambda b, j, t: (jnp.maximum(t[b, j], 0), 0, 0, 0)),
            pl.BlockSpec((1, hk), lambda b, j, t: (jnp.maximum(t[b, j], 0), 0)),
            pl.BlockSpec((1, hk), lambda b, j, t: (jnp.maximum(t[b, j], 0), 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, h, d), lambda b, j, t: (b, 0, 0)),
            pl.BlockSpec((1, h), lambda b, j, t: (b, 0)),
            pl.BlockSpec((1, h), lambda b, j, t: (b, 0)),
            pl.BlockSpec((1, 1, h), lambda b, j, t: (b, j, 0)),
            pl.BlockSpec((1, 1, h), lambda b, j, t: (b, j, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((hk, g, d), jnp.float32),
            pltpu.VMEM((hk, g), jnp.float32),
            pltpu.VMEM((hk, g), jnp.float32),
        ],
    )
    out_shape = [
        jax.ShapeDtypeStruct((b, h, d), jnp.float32),
        jax.ShapeDtypeStruct((b, h), jnp.float32),
        jax.ShapeDtypeStruct((b, h), jnp.float32),
        jax.ShapeDtypeStruct((b, mp, h), jnp.float32),
        jax.ShapeDtypeStruct((b, mp, h), jnp.float32),
    ]
    return pl.pallas_call(kernel, grid_spec=grid_spec, out_shape=out_shape,
                          interpret=interpret)(slot_table, q, k_pool, v_pool, sk, sv)
