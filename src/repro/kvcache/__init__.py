# RARO-tiered paged KV cache (the paper's technique on TPU, DESIGN.md §2B).
from repro.kvcache import paged, quant, tiers  # noqa: F401
