"""Tiered paged KV cache — the RARO technique as a TPU serving feature.

Layout (one attention layer; the serving loop scans layers over a stacked
pytree):

  * an open-page WRITE BUFFER per sequence, bf16 (fresh tokens always start
    at full precision — flash analogue: data lands in the write path before
    any mode decision);
  * three fixed POOLS, one per tier: bf16 / int8 / packed-int4 pages of
    ``page_size`` tokens with per-(page, head) scales (tier ids == flash
    mode ids, see core.modes);
  * a (tier, slot) page table per sequence plus per-logical-page metadata
    (hotness = decayed attention mass, birth step, requant count, reads)
    feeding the RARO controller in tiers.py.

All ops are jit-friendly with static shapes; masked scatters use the
drop-OOB discipline.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import modes
from repro.kvcache import quant


@dataclass(frozen=True)
class CacheConfig:
    n_seqs: int
    max_pages: int  # logical pages per sequence
    page_size: int
    n_kv_heads: int
    head_dim: int
    pool_pages: tuple[int, int, int] = (64, 128, 1024)  # bf16 / int8 / int4
    migrate_per_step: int = 8
    # pool-pressure watermarks for elastic recovery (fraction occupied)
    high_watermark: float = 0.9


class TieredKV(NamedTuple):
    # write buffer (open page per sequence)
    buf_k: jnp.ndarray  # (B, P, Hk, Dh) bf16
    buf_v: jnp.ndarray
    # pools
    k16: jnp.ndarray  # (N0, P, Hk, Dh) bf16
    v16: jnp.ndarray
    k8: jnp.ndarray  # (N1, P, Hk, Dh) int8
    v8: jnp.ndarray
    sk8: jnp.ndarray  # (N1, Hk) f32
    sv8: jnp.ndarray
    k4: jnp.ndarray  # (N2, P, Hk, Dh//2) packed int4
    v4: jnp.ndarray
    sk4: jnp.ndarray
    sv4: jnp.ndarray
    # page tables
    tier: jnp.ndarray  # (B, MaxP) int32, -1 = empty
    slot: jnp.ndarray  # (B, MaxP) int32
    seq_len: jnp.ndarray  # (B,) int32
    # pool free masks
    free: tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]  # (Nt,) bool each
    # per-logical-page metadata (RARO inputs)
    hot: jnp.ndarray  # (B, MaxP) f32 decayed attention mass
    born: jnp.ndarray  # (B, MaxP) i32 step of commit
    requants: jnp.ndarray  # (B, MaxP) i32 quantization events
    reads: jnp.ndarray  # (B, MaxP) f32 attention-mass-weighted reads
    step: jnp.ndarray  # i32 scalar


def init(cfg: CacheConfig, dtype=jnp.bfloat16) -> TieredKV:
    b, mp, p, hk, dh = cfg.n_seqs, cfg.max_pages, cfg.page_size, cfg.n_kv_heads, cfg.head_dim
    n0, n1, n2 = cfg.pool_pages
    f32, i32 = jnp.float32, jnp.int32
    return TieredKV(
        buf_k=jnp.zeros((b, p, hk, dh), dtype),
        buf_v=jnp.zeros((b, p, hk, dh), dtype),
        k16=jnp.zeros((n0, p, hk, dh), dtype),
        v16=jnp.zeros((n0, p, hk, dh), dtype),
        k8=jnp.zeros((n1, p, hk, dh), jnp.int8),
        v8=jnp.zeros((n1, p, hk, dh), jnp.int8),
        sk8=jnp.ones((n1, hk), f32),
        sv8=jnp.ones((n1, hk), f32),
        k4=jnp.zeros((n2, p, hk, dh // 2), jnp.int8),
        v4=jnp.zeros((n2, p, hk, dh // 2), jnp.int8),
        sk4=jnp.ones((n2, hk), f32),
        sv4=jnp.ones((n2, hk), f32),
        tier=jnp.full((b, mp), -1, i32),
        slot=jnp.full((b, mp), -1, i32),
        seq_len=jnp.zeros((b,), i32),
        free=tuple(jnp.ones((n,), bool) for n in (n0, n1, n2)),
        hot=jnp.zeros((b, mp), f32),
        born=jnp.zeros((b, mp), i32),
        requants=jnp.zeros((b, mp), i32),
        reads=jnp.zeros((b, mp), f32),
        step=jnp.int32(0),
    )


def _alloc(free, want_b):
    """Allocate one slot per True entry of want_b (B,). Returns (slots (B,),
    new free). Over-subscription yields -1 for the losers."""
    n = free.shape[0]
    b = want_b.shape[0]
    order = jnp.argsort(~free)  # free slots first
    rank = jnp.cumsum(want_b.astype(jnp.int32)) - 1
    avail = free.sum()
    slots = jnp.where(want_b & (rank < avail), order[jnp.clip(rank, 0, n - 1)], -1)
    new_free = free.at[jnp.where(slots >= 0, slots, n)].set(False, mode="drop")
    return slots.astype(jnp.int32), new_free


def _store_page(pools, tier_id: int, slots, kpage, vpage):
    """Write full pages (B, P, Hk, Dh) bf16 into pool ``tier_id`` at
    ``slots`` (B,), masked where slot < 0. Returns updated pool arrays."""
    (k16, v16, k8, v8, sk8, sv8, k4, v4, sk4, sv4) = pools
    n = [k16, k8, k4][tier_id].shape[0]
    idx = jnp.where(slots >= 0, slots, n)
    if tier_id == modes.TIER_BF16:
        k16 = k16.at[idx].set(kpage.astype(k16.dtype), mode="drop")
        v16 = v16.at[idx].set(vpage.astype(v16.dtype), mode="drop")
    elif tier_id == modes.TIER_INT8:
        qk, sk = quant.quantize_int8(kpage)
        qv, sv = quant.quantize_int8(vpage)
        k8 = k8.at[idx].set(qk, mode="drop")
        v8 = v8.at[idx].set(qv, mode="drop")
        sk8 = sk8.at[idx].set(sk, mode="drop")
        sv8 = sv8.at[idx].set(sv, mode="drop")
    else:
        qk, sk = quant.quantize_int4(kpage)
        qv, sv = quant.quantize_int4(vpage)
        k4 = k4.at[idx].set(qk, mode="drop")
        v4 = v4.at[idx].set(qv, mode="drop")
        sk4 = sk4.at[idx].set(sk, mode="drop")
        sv4 = sv4.at[idx].set(sv, mode="drop")
    return (k16, v16, k8, v8, sk8, sv8, k4, v4, sk4, sv4)


def _load_page(c: TieredKV, tiers, slots, dtype=jnp.bfloat16):
    """Gather + dequantize logical pages. tiers/slots: (...,) -> K,V of
    shape (..., P, Hk, Dh). Invalid (tier<0) pages come back as zeros."""
    t = jnp.maximum(tiers, 0)
    s0 = jnp.clip(slots, 0, c.k16.shape[0] - 1)
    s1 = jnp.clip(slots, 0, c.k8.shape[0] - 1)
    s2 = jnp.clip(slots, 0, c.k4.shape[0] - 1)
    k = jnp.where(
        (t == 0)[..., None, None, None],
        c.k16[s0].astype(dtype),
        jnp.where(
            (t == 1)[..., None, None, None],
            quant.dequantize_int8(c.k8[s1], c.sk8[s1], dtype),
            quant.dequantize_int4(c.k4[s2], c.sk4[s2], dtype),
        ),
    )
    v = jnp.where(
        (t == 0)[..., None, None, None],
        c.v16[s0].astype(dtype),
        jnp.where(
            (t == 1)[..., None, None, None],
            quant.dequantize_int8(c.v8[s1], c.sv8[s1], dtype),
            quant.dequantize_int4(c.v4[s2], c.sv4[s2], dtype),
        ),
    )
    valid = (tiers >= 0)[..., None, None, None]
    return jnp.where(valid, k, 0), jnp.where(valid, v, 0)


def append(c: TieredKV, cfg: CacheConfig, k_new, v_new, commit_tier):
    """Append one token's KV per sequence (k_new/v_new: (B, Hk, Dh)).

    When a buffer page fills, it is committed to the pool of
    ``commit_tier[b]`` (the RARO write-path decision from tiers.py).
    """
    b, p = cfg.n_seqs, cfg.page_size
    off = c.seq_len % p
    bidx = jnp.arange(b)
    buf_k = c.buf_k.at[bidx, off].set(k_new.astype(c.buf_k.dtype))
    buf_v = c.buf_v.at[bidx, off].set(v_new.astype(c.buf_v.dtype))
    seq_len = c.seq_len + 1
    page_full = (seq_len % p) == 0
    page_idx = (seq_len - 1) // p  # logical page being committed

    pools = (c.k16, c.v16, c.k8, c.v8, c.sk8, c.sv8, c.k4, c.v4, c.sk4, c.sv4)
    free = list(c.free)
    tier_tab, slot_tab = c.tier, c.slot
    born, requants = c.born, c.requants
    commit = jnp.asarray(commit_tier, jnp.int32)
    for t in (modes.TIER_BF16, modes.TIER_INT8, modes.TIER_INT4):
        want = page_full & (commit == t)
        slots, free[t] = _alloc(free[t], want)
        # pool exhausted -> fall back to the next denser tier (flash
        # analogue: no free low-density block, data stays dense)
        failed = want & (slots < 0)
        commit = jnp.where(failed, jnp.minimum(t + 1, modes.TIER_INT4), commit)
        pools = _store_page(pools, t, slots, buf_k, buf_v)
        ok = slots >= 0
        mp = cfg.max_pages
        at = (jnp.where(ok, bidx, b), jnp.where(ok, jnp.minimum(page_idx, mp - 1), 0))
        tier_tab = tier_tab.at[at].set(t, mode="drop")
        slot_tab = slot_tab.at[at].set(slots, mode="drop")
        born = born.at[at].set(c.step, mode="drop")
        requants = requants.at[at].add(jnp.where(t == modes.TIER_BF16, 0, 1), mode="drop")

    (k16, v16, k8, v8, sk8, sv8, k4, v4, sk4, sv4) = pools
    return c._replace(
        buf_k=buf_k, buf_v=buf_v, k16=k16, v16=v16, k8=k8, v8=v8, sk8=sk8,
        sv8=sv8, k4=k4, v4=v4, sk4=sk4, sv4=sv4, tier=tier_tab, slot=slot_tab,
        seq_len=seq_len, free=tuple(free), born=born, requants=requants,
        step=c.step + 1,
    )


def gather_kv(c: TieredKV, cfg: CacheConfig, dtype=jnp.bfloat16):
    """Reference read path: dequantize every committed page into dense
    (B, MaxP, P, Hk, Dh) K/V (tests + jnp serving reference; the Pallas
    tiered_attention kernel replaces this on TPU)."""
    return _load_page(c, c.tier, c.slot, dtype)


def pool_occupancy(c: TieredKV):
    return tuple(1.0 - f.mean() for f in c.free)


def memory_bytes(c: TieredKV, cfg: CacheConfig):
    """HBM bytes of committed pages (the 'capacity' axis of the paper)."""
    p, hk, dh = cfg.page_size, cfg.n_kv_heads, cfg.head_dim
    page_b = {0: 2 * p * hk * dh * 2, 1: 2 * p * hk * dh, 2: p * hk * dh}
    used = [(~f).sum() for f in c.free]
    return sum(int(u) * page_b[t] for t, u in enumerate(used))
