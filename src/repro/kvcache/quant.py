"""KV-page quantization: bf16 <-> int8 <-> packed int4, per-(page, head)
symmetric scales. These are the three RARO tiers (DESIGN.md §2B):

  tier 0 (SLC analogue)  bf16   — fastest/most-reliable read
  tier 1 (TLC analogue)  int8
  tier 2 (QLC analogue)  int4   — densest, highest dequant error

Pure-jnp reference implementations; kernels/quant_page is the Pallas
migration kernel validated against these.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import modes

INT4_MAX = 7.0
INT8_MAX = 127.0


def quant_scales(x, qmax: float):
    """x: (..., P, H, D) -> per-(page-leading..., H) scale over (P, D)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(-3, -1))
    return jnp.maximum(amax, 1e-8) / qmax


def quantize_int8(x):
    s = quant_scales(x, INT8_MAX)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None, :, None]), -127, 127)
    return q.astype(jnp.int8), s


def dequantize_int8(q, s, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * s[..., None, :, None]).astype(dtype)


def pack_int4(q):
    """int8 values in [-8, 7], (..., D) with even D -> (..., D//2) packed."""
    lo = q[..., 0::2] & 0x0F
    hi = (q[..., 1::2] & 0x0F) << 4
    return (lo | hi).astype(jnp.int8)


def unpack_int4(p):
    """(..., D//2) packed -> (..., D) sign-extended int8 in [-8, 7]."""
    lo = ((p & 0x0F) ^ 0x08) - 0x08  # sign-extend low nibble
    hi = p >> 4  # arithmetic shift sign-extends the high nibble
    d2 = p.shape[-1]
    out = jnp.stack([lo, hi], axis=-1).reshape(*p.shape[:-1], 2 * d2)
    return out.astype(jnp.int8)


def quantize_int4(x):
    s = quant_scales(x, INT4_MAX)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None, :, None]), -7, 7)
    return pack_int4(q.astype(jnp.int8)), s


def dequantize_int4(p, s, dtype=jnp.bfloat16):
    q = unpack_int4(p)
    return (q.astype(jnp.float32) * s[..., None, :, None]).astype(dtype)


def quant_error(x, tier: int):
    """Relative RMS dequantization error of storing x at ``tier`` — the
    Layer-B analogue of the paper's RBER (the 'raw error rate' of the denser
    medium). Returns per-(..., H) float32."""
    x32 = x.astype(jnp.float32)
    if tier == modes.TIER_BF16:
        return jnp.zeros(x.shape[:-3] + (x.shape[-2],), jnp.float32)
    if tier == modes.TIER_INT8:
        q, s = quantize_int8(x)
        xd = dequantize_int8(q, s, jnp.float32)
    else:
        q, s = quantize_int4(x)
        xd = dequantize_int4(q, s, jnp.float32)
    num = jnp.sqrt(jnp.mean((x32 - xd) ** 2, axis=(-3, -1)))
    den = jnp.sqrt(jnp.mean(x32**2, axis=(-3, -1))) + 1e-8
    return num / den
