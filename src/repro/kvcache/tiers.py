"""RARO tier controller for the paged KV cache.

Drives the SAME policy code as the flash simulator (core.policy Table II,
core.hotness, core.retry Eq. 3), with the Layer-B variable mapping of
DESIGN.md §2B:

  flash mode       -> KV tier            (ids shared, core.modes)
  P/E cycles       -> requantization events per page
  retention time   -> page age in decode steps
  read disturbs    -> accumulated attention mass ("reads")
  RBER             -> relative dequant error of the tier
  read retry count -> Eq.-3 correction-cost estimate from that error

Differences from flash, stated plainly: quantization error is NOT
recoverable by promotion (no ECC on lost bits), so the write-path commit
decision (which tier a freshly filled page lands in) is heat-aware — the
paper's read-triggered conversion then corrects mistakes in both
directions. Elastic capacity recovery demotes cold pages under pool
pressure exactly like Fig. 12.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.core import hotness, modes, policy, retry
from repro.kvcache import paged, quant


@dataclass(frozen=True)
class RAROConfig:
    heat: hotness.HeatConfig = field(
        default_factory=lambda: hotness.HeatConfig(decay=0.95, hot_thresh=0.08, warm_thresh=0.02)
    )
    r1: int = 1
    r2: int = 5
    # Layer-B stress scaling: map (requants, age, reads) onto the Eq.-1
    # input ranges the flash constants were calibrated for.
    cycles_per_requant: float = 120.0
    hours_per_step: float = 0.05
    reads_scale: float = 40.0
    enabled: bool = True  # False -> static tiers (baseline)


def page_retry_estimate(c: paged.TieredKV, rcfg: RAROConfig):
    """Eq.(1) -> Eq.(3) per logical page, using its tier as the mode."""
    tier = jnp.maximum(c.tier, modes.SLC)
    cycles = c.requants.astype(jnp.float32) * rcfg.cycles_per_requant
    age_h = (c.step - c.born).astype(jnp.float32) * rcfg.hours_per_step
    reads = c.reads * rcfg.reads_scale
    page_ids = jnp.arange(c.tier.size, dtype=jnp.int32).reshape(c.tier.shape)
    n = retry.page_retries(tier, cycles, age_h, reads, page_ids)
    return jnp.where(c.tier >= 0, n, 0)


def update_stats(c: paged.TieredKV, masses, rcfg: RAROConfig):
    """Fold one decode step's per-page attention masses (B, MaxP) into the
    hotness/reads metadata."""
    hot = hotness.decay_heat(c.hot, rcfg.heat) + masses
    return c._replace(hot=hot, reads=c.reads + masses)


def commit_tier(c: paged.TieredKV, cfg: paged.CacheConfig, rcfg: RAROConfig):
    """Write-path decision: tier for the page each sequence commits next.

    Uses the hotness of the sequence's most recent committed page as the
    predictor (hot sequences keep attending their recent context)."""
    if not rcfg.enabled:
        return jnp.full((cfg.n_seqs,), modes.TIER_INT4, jnp.int32)
    last = jnp.maximum(c.seq_len // cfg.page_size - 1, 0)
    bidx = jnp.arange(cfg.n_seqs)
    h = c.hot[bidx, last]
    cls = hotness.classify(h, rcfg.heat)
    return jnp.where(
        cls == modes.HOT,
        modes.TIER_BF16,
        jnp.where(cls == modes.WARM, modes.TIER_INT8, modes.TIER_INT4),
    ).astype(jnp.int32)


def _move_pages(c: paged.TieredKV, cfg: paged.CacheConfig, sel_b, sel_p, tgt: int):
    """Migrate up to M logical pages (sel_b/sel_p, -1-padded) to tier tgt."""
    b_safe = jnp.maximum(sel_b, 0)
    p_safe = jnp.maximum(sel_p, 0)
    cur_tier = c.tier[b_safe, p_safe]
    cur_slot = c.slot[b_safe, p_safe]
    ok = (sel_b >= 0) & (cur_tier >= 0) & (cur_tier != tgt)

    kpage, vpage = paged._load_page(c, jnp.where(ok, cur_tier, -1), cur_slot)

    free = list(c.free)
    slots, free[tgt] = paged._alloc(free[tgt], ok)
    moved = ok & (slots >= 0)

    pools = (c.k16, c.v16, c.k8, c.v8, c.sk8, c.sv8, c.k4, c.v4, c.sk4, c.sv4)
    pools = paged._store_page(pools, tgt, jnp.where(moved, slots, -1), kpage, vpage)

    # release source slots
    for t in range(3):
        rel = moved & (cur_tier == t)
        n = free[t].shape[0]
        free[t] = free[t].at[jnp.where(rel, cur_slot, n)].set(True, mode="drop")

    B = cfg.n_seqs
    at = (jnp.where(moved, b_safe, B), p_safe)
    tier_tab = c.tier.at[at].set(tgt, mode="drop")
    slot_tab = c.slot.at[at].set(slots, mode="drop")
    requants = c.requants.at[at].add(0 if tgt == modes.TIER_BF16 else 1, mode="drop")
    # conversion resets the page's stress clock (fresh program, Fig. 8)
    born = c.born.at[at].set(c.step, mode="drop")
    reads = c.reads.at[at].set(0.0, mode="drop")

    (k16, v16, k8, v8, sk8, sv8, k4, v4, sk4, sv4) = pools
    return c._replace(
        k16=k16, v16=v16, k8=k8, v8=v8, sk8=sk8, sv8=sv8, k4=k4, v4=v4,
        sk4=sk4, sv4=sv4, tier=tier_tab, slot=slot_tab, free=tuple(free),
        requants=requants, born=born, reads=reads,
    ), moved.sum()


def _topk_pages(score, m):
    """Top-m (b, p) indices of a (B, MaxP) score; -1 where score = -inf."""
    b, mp = score.shape
    flat = score.reshape(-1)
    v, i = jax.lax.top_k(flat, m)
    ok = v > -jnp.inf
    return jnp.where(ok, i // mp, -1), jnp.where(ok, i % mp, -1)


def raro_step(c: paged.TieredKV, cfg: paged.CacheConfig, rcfg: RAROConfig, masses):
    """One controller invocation between decode steps (paper Fig. 11):
    1. heat classifier   2. RBER/retry estimate   3. Table-II migration,
    plus elastic capacity recovery under pool pressure."""
    c = update_stats(c, masses, rcfg)
    if not rcfg.enabled:
        return c, {}

    retries = page_retry_estimate(c, rcfg)
    cls = hotness.classify(c.hot, rcfg.heat)
    th = policy.Thresholds(jnp.int32(rcfg.r1), jnp.int32(rcfg.r2))
    tier = jnp.where(c.tier >= 0, c.tier, modes.SLC)  # invalid pages -> SLC (never migrates)
    target = policy.migration_decision(tier, cls, retries, th)
    target = jnp.where(c.tier >= 0, target, c.tier)

    stats = {}
    m = cfg.migrate_per_step
    for tgt in (modes.TIER_BF16, modes.TIER_INT8):
        trig = (c.tier >= 0) & (target == tgt) & (c.tier > tgt)
        score = jnp.where(trig, c.hot, -jnp.inf)
        sb, sp = _topk_pages(score, m)
        c, n = _move_pages(c, cfg, sb, sp, tgt)
        stats[f"promoted_to_{modes.TIER_NAMES[tgt]}"] = n

    # ---- elastic capacity recovery (Fig. 12): demote cold pages under
    # pool pressure, one density level at a time ----
    occ = paged.pool_occupancy(c)
    for src in (modes.TIER_BF16, modes.TIER_INT8):
        pressure = occ[src] > cfg.high_watermark
        cold = (c.tier == src) & (cls == modes.COLD)
        score = jnp.where(cold & pressure, -c.hot, -jnp.inf)
        sb, sp = _topk_pages(score, m)
        c, n = _move_pages(c, cfg, sb, sp, src + 1)
        stats[f"demoted_from_{modes.TIER_NAMES[src]}"] = n
    return c, stats
