import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes — 16x16 (single pod, 256 chips) and 2x16x16
(2 pods, 512 chips) — against ShapeDtypeStruct inputs (zero allocation).

Per cell we record memory_analysis, cost_analysis (FLOPs/bytes) and the
post-SPMD collective table (op kind, payload bytes, whether it sits inside
the layer-scan while body) into results/dryrun/<mesh>/<arch>__<shape>.json,
which §Roofline consumes.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh single|multi|both] [--force]
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCHS, SHAPES, applicable
from repro.launch.mesh import make_production_mesh
from repro.models import base, registry
from repro.parallel import sharding
from repro.serving import serve_step as ss
from repro.training import optim
from repro.training import train_step as ts

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

_COLL_RE = re.compile(
    r"(\w+[\d.\-]*)\s*=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\(",
)
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8, "s32": 4,
    "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}


_CALL_RE = re.compile(r"(?:calls=|to_apply=)%([\w.\-]+)")
_WHILE_RE = re.compile(r"while\([^)]*\), condition=%([\w.\-]+), body=%([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def parse_collectives(hlo_text: str) -> list[dict]:
    """Collective ops from post-partitioning HLO with correct loop
    multipliers: build the computation call graph, read each while loop's
    trip count from its condition's s32 constant, and multiply collective
    payloads by the product of enclosing trip counts."""
    # 1. split into computations
    comps: dict[str, list[str]] = {}
    cur = ""
    for line in hlo_text.splitlines():
        if not line.startswith(" ") and ("{" in line):
            head = line.split(" ")[0].lstrip("%")
            if head == "ENTRY":
                head = line.split(" ")[1].lstrip("%")
            cur = head
            comps[cur] = []
        elif cur:
            comps[cur].append(line)
        if line.startswith("ENTRY"):
            cur = line.split(" ")[1].split("(")[0].lstrip("%")
            comps[cur] = []

    # 2. edges: (caller -> callee, multiplier)
    trip_of_body: dict[str, int] = {}
    edges: dict[str, list[tuple[str, int]]] = {c: [] for c in comps}
    for name, lines in comps.items():
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trip = 1
                for cl in comps.get(cond, []):
                    for c in _CONST_RE.findall(cl):
                        trip = max(trip, int(c))
                trip_of_body[body] = trip
                edges[name].append((body, trip))
                edges[name].append((cond, trip))
            for callee in _CALL_RE.findall(line):
                edges[name].append((callee, 1))

    # 3. propagate multipliers from roots (computations never called)
    called = {c for outs in edges.values() for c, _ in outs}
    mult: dict[str, int] = {c: 1 for c in comps if c not in called}
    frontier = list(mult)
    while frontier:
        nxt = []
        for c in frontier:
            for callee, k in edges.get(c, []):
                m = mult[c] * k
                if m > mult.get(callee, 0):
                    mult[callee] = m
                    nxt.append(callee)
        frontier = nxt

    # 4. collect collectives with their computation's multiplier
    out = []
    for name, lines in comps.items():
        m = mult.get(name, 1)
        for line in lines:
            for cm in _COLL_RE.finditer(line):
                dtype, dims, kind = cm.group(2), cm.group(3), cm.group(4)
                n = 1
                for d in dims.split(","):
                    if d.strip():
                        n *= int(d)
                out.append({
                    "kind": kind,
                    "bytes": n * _DTYPE_BYTES.get(dtype, 4),
                    "mult": m,
                })
    return out


def _tree_bytes(tree) -> int:
    import numpy as np

    return int(
        sum(np.prod(x.shape) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))
    )


VARIANTS = ("seq_shard", "xent_chunk", "moe_hints", "kv8", "kv4")


def _chunk_for(txt_len: int) -> int:
    for c in (2048, 1920, 1536, 1280, 1024, 960, 768, 640, 512, 384, 256, 128):
        if txt_len % c == 0:
            return c
    return 0


def apply_variants(cfg, shape, variants: tuple[str, ...]):
    """§Perf iteration knobs -> config overrides (recorded per cell)."""
    ov = {}
    seq_shard = "seq_shard" in variants
    if "xent_chunk" in variants and shape.kind == "train":
        n_txt = shape.seq_len - (cfg.n_img_tokens if cfg.family == "vlm" else 0)
        c = _chunk_for(n_txt)
        if c:
            ov["xent_chunk"] = c
    if "moe_hints" in variants and cfg.n_experts:
        ov["moe_hints"] = True
    if "kv8" in variants and cfg.family in ("dense", "vlm") and shape.kind == "decode":
        ov["kv_bits"] = 8
    if "kv4" in variants and cfg.family in ("dense", "vlm") and shape.kind == "decode":
        ov["kv_bits"] = 4
    return cfg.with_(**ov) if ov else cfg, ov, seq_shard


def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str,
               variants: tuple[str, ...] = ()):
    shape = SHAPES[shape_name]
    cfg, overrides, seq_shard = apply_variants(ARCHS[arch], shape, variants)
    api = registry.get_api(cfg)
    specs = api.specs()
    params_abs = base.abstract(specs)
    p_shard = sharding.param_shardings(cfg, specs, mesh)
    inputs = registry.input_specs(cfg, shape)

    if shape.kind == "train":
        ocfg = optim.AdamWConfig()
        o_specs = optim.opt_state_specs(specs)
        o_abs = base.abstract(o_specs)
        o_shard = base.param_shardings(o_specs, mesh, sharding.make_rules(cfg, mesh))
        b_shard = sharding.batch_shardings(cfg, inputs, mesh)
        step = ts.make_train_step(cfg, ocfg)
        fn = jax.jit(
            step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
        )
        args = (params_abs, o_abs, inputs)
    elif shape.kind == "prefill":
        b_shard = sharding.batch_shardings(cfg, inputs, mesh)
        fn = jax.jit(ss.make_prefill(cfg), in_shardings=(p_shard, b_shard))
        args = (params_abs, inputs)
    else:  # decode
        cache_abs = inputs["cache"]
        c_shard = sharding.cache_shardings(cfg, cache_abs, mesh, seq_shard=seq_shard)
        tok_shard = sharding.batch_shardings(
            cfg, {"tokens": inputs["tokens"], "pos": inputs["pos"]}, mesh
        )
        fn = jax.jit(
            ss.make_serve_step(cfg),
            in_shardings=(p_shard, c_shard, tok_shard["tokens"], tok_shard["pos"]),
            out_shardings=(None, c_shard),
            donate_argnums=(1,),
        )
        args = (params_abs, cache_abs, inputs["tokens"], inputs["pos"])

    # jax.set_mesh only exists on newer jax; Mesh is itself a context manager
    with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
        t0 = time.time()
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_size_in_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_in_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_in_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_in_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not support it
        mem_d = {"error": str(e)}
    try:
        cost = dict(compiled.cost_analysis() or {})
        cost = {k: float(v) for k, v in cost.items() if isinstance(v, (int, float))}
    except Exception as e:
        cost = {"error": str(e)}

    colls = parse_collectives(compiled.as_text())
    agg: dict[str, dict] = {}
    for c in colls:
        a = agg.setdefault(c["kind"], {"count": 0, "bytes_total": 0, "bytes_once": 0})
        a["count"] += 1
        a["bytes_once"] += c["bytes"]  # static payload, no loop multiplier
        a["bytes_total"] += c["bytes"] * c["mult"]  # executed payload per step

    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "variants": list(variants),
        "overrides": {k: v for k, v in overrides.items()},
        "seq_shard": seq_shard,
        "devices": int(len(mesh.devices.ravel())),
        "n_layers": cfg.n_layers,
        "family": cfg.family,
        "param_bytes_global": _tree_bytes(params_abs),
        "input_bytes_global": _tree_bytes(args[1] if shape.kind == "train" else args[-2]),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem_d,
        "cost_analysis": cost,
        "collectives": agg,
        "status": "ok",
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="", help="comma list of perf knobs: "
                    "seq_shard,xent_chunk,moe_hints,kv8,kv4")
    args = ap.parse_args()
    variants = tuple(v for v in args.variant.split(",") if v)

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16", make_production_mesh(multi_pod=True)))

    n_ok = n_skip = n_fail = 0
    for mesh_name, mesh in meshes:
        suffix = ("__" + "_".join(variants)) if variants else ""
        outdir = RESULTS / (mesh_name + suffix)
        outdir.mkdir(parents=True, exist_ok=True)
        for arch, cfg in ARCHS.items():
            if args.arch and arch != args.arch:
                continue
            for shape_name in SHAPES:
                if args.shape and shape_name != args.shape:
                    continue
                ok, why = applicable(cfg.family, SHAPES[shape_name])
                out = outdir / f"{arch}__{shape_name}.json"
                if not ok:
                    out.write_text(json.dumps(
                        {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                         "status": "skipped", "reason": why}, indent=1))
                    n_skip += 1
                    print(f"[skip] {mesh_name} {arch} {shape_name}: {why}", flush=True)
                    continue
                if out.exists() and not args.force:
                    prev = json.loads(out.read_text())
                    if prev.get("status") == "ok":
                        n_ok += 1
                        print(f"[cached] {mesh_name} {arch} {shape_name}", flush=True)
                        continue
                t0 = time.time()
                try:
                    rec = lower_cell(arch, shape_name, mesh, mesh_name, variants)
                    n_ok += 1
                    print(
                        f"[ok] {mesh_name} {arch} {shape_name} "
                        f"lower={rec['lower_s']}s compile={rec['compile_s']}s "
                        f"flops={rec['cost_analysis'].get('flops')}", flush=True,
                    )
                except Exception as e:
                    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                           "status": "fail", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:],
                           "elapsed_s": round(time.time() - t0, 1)}
                    n_fail += 1
                    print(f"[FAIL] {mesh_name} {arch} {shape_name}: {type(e).__name__}: {e}",
                          flush=True)
                out.write_text(json.dumps(rec, indent=1))
    print(f"dry-run done: ok={n_ok} skipped={n_skip} failed={n_fail}", flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
