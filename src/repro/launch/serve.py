"""Batched serving driver with the RARO-tiered KV cache.

Serves a small dense LM: prefill a batch of prompts, decode with the
tiered paged cache (Pallas tiered_attention in interpret mode on CPU),
running the RARO controller between steps. Reports throughput, tier
occupancy / HBM bytes, and output-quality drift vs an all-bf16 cache —
the serving analogue of the paper's IOPS-vs-capacity trade.

  PYTHONPATH=src python -m repro.launch.serve --steps 64
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import modes
from repro.kernels.tiered_attention.ops import tiered_decode_attention
from repro.kvcache import paged, tiers
from repro.models import base, layers as L, registry, transformer as T


def serve_cfg(vocab=512, d_model=128, n_layers=4, n_heads=4, n_kv=2):
    return ModelConfig(arch="serve-demo", family="dense", n_layers=n_layers,
                       d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
                       d_ff=256, vocab=vocab, dtype=jnp.float32, remat=False)


def tiered_decode_step(params, caches, cache_cfg, rcfg, tokens, pos, cfg):
    """decode_step variant whose attention reads the tiered paged cache.
    ``caches`` is a list of (TieredKV) per layer."""
    b = tokens.shape[0]
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    new_caches = []
    layer_params = [jax.tree_util.tree_map(lambda a: a[i], params["layers"])
                    for i in range(cfg.n_layers)]
    for lp, c in zip(layer_params, caches):
        xn = T.norm(cfg, lp["ln1"], x)
        q, k, v = T.qkv(lp["attn"], xn, cfg, pos[:, None])
        ct = tiers.commit_tier(c, cache_cfg, rcfg)
        c = paged.append(c, cache_cfg, k[:, 0], v[:, 0], ct)
        o, mass = tiered_decode_attention(q[:, 0], c, cache_cfg)
        c, _ = tiers.raro_step(c, cache_cfg, rcfg, mass)
        h = x + o[:, None].reshape(b, 1, -1).astype(cfg.dtype) @ lp["attn"]["wo"]
        x = h + L.mlp(lp["mlp"], T.norm(cfg, lp["ln2"], h), cfg.act)
        new_caches.append(c)
    x = T.norm(cfg, params["ln_f"], x)
    return L.lm_logits(params["embed"], x, cfg.vocab), new_caches


def run(steps=64, batch=4, raro_enabled=True, seed=0, cfg=None, params=None,
        quiet=False):
    cfg = cfg or serve_cfg()
    api = registry.get_api(cfg)
    if params is None:
        params = base.materialize(api.specs(), jax.random.PRNGKey(seed), jnp.float32)

    hk, dh = cfg.n_kv_heads, cfg.head_dim
    ccfg = paged.CacheConfig(n_seqs=batch, max_pages=max(steps // 8 + 2, 4),
                             page_size=8, n_kv_heads=hk, head_dim=dh,
                             pool_pages=(8, 16, 256), migrate_per_step=4)
    rcfg = tiers.RAROConfig(enabled=raro_enabled)
    caches = [paged.init(ccfg, jnp.float32) for _ in range(cfg.n_layers)]

    # reference: exact bf16 cache decode for quality comparison
    ref_cache = {k: jnp.zeros((cfg.n_layers, batch, steps + 1, hk, dh), jnp.float32)
                 for k in ("k", "v")}

    tok = jax.random.randint(jax.random.PRNGKey(seed + 1), (batch, 1), 0, cfg.vocab)
    ref_tok = tok
    drift = []
    t0 = time.time()
    for t in range(steps):
        pos = jnp.full((batch,), t, jnp.int32)
        logits, caches = tiered_decode_step(params, caches, ccfg, rcfg, tok, pos, cfg)
        ref_logits, ref_cache = T.decode_step(params, ref_cache, ref_tok, pos, cfg)
        d = jnp.mean(jnp.abs(jax.nn.softmax(logits[:, -1].astype(jnp.float32))
                             - jax.nn.softmax(ref_logits[:, -1].astype(jnp.float32))))
        drift.append(float(d))
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        ref_tok = jnp.argmax(ref_logits[:, -1], -1).astype(jnp.int32)[:, None]
    dt = time.time() - t0

    occ = [paged.pool_occupancy(c) for c in caches]
    mem = sum(paged.memory_bytes(c, ccfg) for c in caches)
    mem_bf16 = sum(
        int((~f).sum()) * 2 * ccfg.page_size * hk * dh * 2
        for c in caches for f in [c.free[0] | ~c.free[0]]  # all pages at bf16
    ) or 1
    committed = sum(int((np.asarray(c.tier) >= 0).sum()) for c in caches)
    bf16_equiv = committed * 2 * ccfg.page_size * hk * dh * 2
    tier_hist = np.zeros(3, int)
    for c in caches:
        tt = np.asarray(c.tier)
        for i in range(3):
            tier_hist[i] += (tt == i).sum()
    out = {
        "tok_per_s": batch * steps / dt,
        "mean_prob_drift": float(np.mean(drift)),
        "final_prob_drift": float(drift[-1]),
        "kv_bytes": mem,
        "kv_bytes_bf16_equiv": bf16_equiv,
        "capacity_saving": 1.0 - mem / max(bf16_equiv, 1),
        "tier_pages": tier_hist.tolist(),
    }
    if not quiet:
        for k, v in out.items():
            print(f"  {k}: {v}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    a = ap.parse_args()
    print("== RARO tiered KV serving ==")
    run(steps=a.steps, batch=a.batch, raro_enabled=True)
    print("== static int4-only baseline (QLC analogue) ==")
    run(steps=a.steps, batch=a.batch, raro_enabled=False)


if __name__ == "__main__":
    main()
