"""End-to-end training driver.

Runs a real training loop (synthetic-but-learnable data) with checkpoint
rotation, async saves, crash-resume, and optional gradient compression —
on whatever mesh the process sees (1 CPU device for the examples; the same
code path pjit-shards on a real pod).

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --smoke --steps 300 --batch 16 --seq 128
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCHS, smoke_variant
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import base, registry
from repro.parallel import sharding
from repro.training import optim
from repro.training import train_step as ts


def run(arch: str, *, smoke: bool = True, steps: int = 300, batch: int = 16,
        seq: int = 128, microbatches: int = 1, ckpt_dir: str | None = None,
        ckpt_interval: int = 100, lr: float = 1e-3, log_every: int = 20,
        mesh=None):
    cfg = ARCHS[arch]
    if smoke:
        cfg = smoke_variant(cfg)
    if mesh is None:
        n = len(jax.devices())
        mesh = jax.make_mesh((n, 1), ("data", "model"))

    api = registry.get_api(cfg)
    specs = api.specs()
    params = base.materialize(specs, jax.random.PRNGKey(0))
    ocfg = optim.AdamWConfig(lr=lr, warmup=20, total_steps=steps)
    opt_state = optim.init(params)

    p_shard = sharding.param_shardings(cfg, specs, mesh)
    params = jax.device_put(params, p_shard)

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch))
    step_fn = jax.jit(ts.make_train_step(cfg, ocfg, microbatches=microbatches),
                      donate_argnums=(0, 1))

    mgr = CheckpointManager(ckpt_dir, interval=ckpt_interval) if ckpt_dir else None
    start = 0
    if mgr is not None:
        restored = mgr.restore_latest((params, opt_state))
        if restored is not None:
            start, (params, opt_state), _ = restored
            print(f"resumed from step {start}")

    hist = []
    t0 = time.time()
    # jax.set_mesh only exists on newer jax; Mesh is itself a context manager
    with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
        for step in range(start, steps):
            b = data.batch_at(step)
            b = {k: jnp.asarray(v) for k, v in b.items()}
            params, opt_state, metrics = step_fn(params, opt_state, b)
            if step % log_every == 0 or step == steps - 1:
                loss = float(metrics["loss"])
                hist.append((step, loss))
                print(f"step {step:5d} loss {loss:.4f} gnorm "
                      f"{float(metrics['grad_norm']):.3f} "
                      f"({(time.time()-t0)/max(step-start+1,1)*1000:.0f} ms/step)",
                      flush=True)
            if mgr is not None and mgr.should_save(step):
                mgr.save(step, (params, opt_state))
    if mgr is not None:
        mgr.save(steps, (params, opt_state))
        mgr.wait()
    return params, hist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=1e-3)
    a = ap.parse_args()
    _, hist = run(a.arch, smoke=a.smoke, steps=a.steps, batch=a.batch, seq=a.seq,
                  microbatches=a.microbatches, ckpt_dir=a.ckpt_dir, lr=a.lr)
    first, last = hist[0][1], hist[-1][1]
    print(f"loss {first:.3f} -> {last:.3f}")


if __name__ == "__main__":
    main()
