# Composable model zoo: one module per family, configs select architectures.
