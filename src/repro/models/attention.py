"""Attention primitives.

``blockwise_attention`` is a pure-jnp flash-attention (online softmax over KV
blocks via lax.scan) so 32k-token prefill never materializes an (S, S) score
matrix; it is also the numerical oracle for the Pallas flash kernel
(kernels/flash_attention). ``decode_attention`` is the single-token path over
a (possibly windowed) KV cache; its Pallas counterpart is
kernels/tiered_attention, which adds in-kernel dequantization of RARO KV
tiers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def _gqa_split(q, n_kv: int):
    """(B, S, H, D) -> (B, S, Hk, G, D) with G = H // Hk."""
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def blockwise_attention(q, k, v, *, causal: bool, q_offset=0, kv_len=None,
                        window: int = 0, block: int = 1024):
    """Online-softmax attention.

    q: (B, Sq, H, D); k, v: (B, Sk, Hk, D); H % Hk == 0.
    q_offset: absolute position of q[0] (for causal masking during decode /
      chunked prefill). kv_len: (B,) valid cache length mask. window > 0
      restricts attention to the last ``window`` positions (sliding window).
    Returns (B, Sq, H, D).
    """
    b, sq, h, d = q.shape
    _, sk, hk, _ = k.shape
    g = h // hk
    block = min(block, sk)
    n_blocks = -(-sk // block)
    pad = n_blocks * block - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qg = _gqa_split(q, hk).astype(jnp.float32) * (d**-0.5)  # (B,Sq,Hk,G,D)
    q_pos = q_offset + jnp.arange(sq)

    kb = k.reshape(b, n_blocks, block, hk, d)
    vb = v.reshape(b, n_blocks, block, hk, v.shape[-1])

    def body(carry, xs):
        m, l, acc = carry
        kj, vj, j = xs
        kj = kj.astype(jnp.float32)
        # scores: (B, Sq, Hk, G, block)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, kj)
        k_pos = j * block + jnp.arange(block)
        mask = jnp.ones((sq, block), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        mask &= (k_pos < sk - pad)[None, :]
        if kv_len is not None:
            mask = mask[None] & (k_pos[None, None, :] < kv_len[:, None, None])
            s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
        else:
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p, vj.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    dv = v.shape[-1]  # v head dim may differ from k (MLA)
    m0 = jnp.full((b, sq, hk, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, hk, g), jnp.float32)
    a0 = jnp.zeros((b, sq, hk, g, dv), jnp.float32)
    (m, l, acc), _ = lax.scan(
        body,
        (m0, l0, a0),
        (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4), jnp.arange(n_blocks)),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, sq, h, dv).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0):
    """One-token attention over the cache.

    q: (B, 1, H, D); caches: (B, S, Hk, D); cache_len: (B,) — entries at
    positions >= cache_len are masked (the cache may be partially filled).
    """
    b, _, h, d = q.shape
    _, s, hk, _ = k_cache.shape
    qg = _gqa_split(q, hk).astype(jnp.float32) * (d**-0.5)
    scores = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k_cache.astype(jnp.float32))
    k_pos = jnp.arange(s)
    mask = k_pos[None, :] < cache_len[:, None]
    if window:
        mask &= k_pos[None, :] >= cache_len[:, None] - window
    scores = jnp.where(mask[:, None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, v_cache.shape[-1]).astype(q.dtype)


def reference_attention(q, k, v, *, causal: bool, q_offset=0, kv_len=None, window: int = 0):
    """Naive O(S^2) oracle for tests."""
    b, sq, h, d = q.shape
    _, sk, hk, _ = k.shape
    qg = _gqa_split(q, hk).astype(jnp.float32) * (d**-0.5)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k.astype(jnp.float32))
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    mask = jnp.broadcast_to(mask[None], (b, sq, sk))
    if kv_len is not None:
        mask &= k_pos[None, None, :] < kv_len[:, None, None]
    s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, v.shape[-1]).astype(q.dtype)
