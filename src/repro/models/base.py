"""Parameter-spec infrastructure.

Models declare parameters as pytrees of :class:`ParamSpec` (shape + logical
axes + initializer). From one spec tree we derive:

  * real parameters        (``materialize`` — tests/examples)
  * abstract parameters    (``abstract`` — multi-pod dry-run, no allocation)
  * shardings              (``named_sharding`` — logical->mesh axis rules)

so the dry-run never allocates a byte and sharding rules live in one place.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]  # logical axis names, len == len(shape)
    init: str = "normal"  # normal | zeros | ones | scaled (fan-in)
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _tree_map(f, tree):
    return jax.tree_util.tree_map(f, tree, is_leaf=is_spec)


def materialize(specs, key, dtype=None):
    """Instantiate real parameters (tests, examples, small-scale training)."""
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))

    def init_one(spec: ParamSpec, k):
        dt = dtype or spec.dtype
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dt)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dt)
        scale = 1.0
        if spec.init == "scaled" and len(spec.shape) >= 2:
            scale = 1.0 / np.sqrt(spec.shape[-2])
        elif spec.init == "normal":
            scale = 0.02
        return (jax.random.normal(k, spec.shape, jnp.float32) * scale).astype(dt)

    out = [init_one(s, k) for s, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract(specs, dtype=None):
    """ShapeDtypeStruct stand-ins — what the dry-run lowers against."""
    return _tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype or s.dtype), specs
    )


def n_params(specs) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))


# ---------------------------------------------------------------------------
# Logical-axis -> mesh-axis rules.
# ---------------------------------------------------------------------------

# Default TP/EP mapping: tensor dims that scale with the model shard over
# "model"; everything else is replicated (data/pod axes shard activations,
# optimizer ZeRO sharding is layered on separately in training/optim.py).
DEFAULT_RULES: dict[str, str | None] = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "experts": "model",
    "embed": None,
    "layers": None,
    "conv": None,
    "state": None,
    None: None,
}


def spec_partition(spec: ParamSpec, rules: dict, mesh) -> P:
    """PartitionSpec for one param, with divisibility fallback to replicate."""
    out = []
    for dim, ax in zip(spec.shape, spec.axes):
        mesh_ax = rules.get(ax, None)
        if mesh_ax is not None and dim % mesh.shape[mesh_ax] == 0:
            out.append(mesh_ax)
        else:
            out.append(None)
    # GSPMD forbids the same mesh axis twice in one spec; keep the first.
    seen = set()
    cleaned = []
    for ax in out:
        if ax is not None and ax in seen:
            cleaned.append(None)
        else:
            cleaned.append(ax)
            if ax is not None:
                seen.add(ax)
    return P(*cleaned)


def param_shardings(specs, mesh, rules: dict | None = None):
    rules = {**DEFAULT_RULES, **(rules or {})}
    return _tree_map(lambda s: NamedSharding(mesh, spec_partition(s, rules, mesh)), specs)


def param_pspecs(specs, mesh, rules: dict | None = None):
    rules = {**DEFAULT_RULES, **(rules or {})}
    return _tree_map(lambda s: spec_partition(s, rules, mesh), specs)
