"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv audio frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (B, enc_len, d_model). LayerNorm +
GELU + sinusoidal positions, bidirectional encoder, causal decoder with
cross-attention. Decode caches: rolling self-attn KV + static cross KV.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.base import ParamSpec


def enc_layer_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": T.norm_specs(cfg),
        "attn": T.attn_specs(cfg),
        "ln2": T.norm_specs(cfg),
        "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff, gated=False),
    }


def dec_layer_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": T.norm_specs(cfg),
        "attn": T.attn_specs(cfg),
        "ln_x": T.norm_specs(cfg),
        "xattn": T.attn_specs(cfg),
        "ln2": T.norm_specs(cfg),
        "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff, gated=False),
    }


def specs(cfg: ModelConfig) -> dict:
    return {
        "embed": L.embedding_specs(cfg.vocab, cfg.d_model),
        "enc_layers": T.stack_specs(cfg.n_enc_layers, enc_layer_specs(cfg)),
        "enc_ln_f": T.norm_specs(cfg),
        "dec_layers": T.stack_specs(cfg.n_layers, dec_layer_specs(cfg)),
        "ln_f": T.norm_specs(cfg),
    }


def encode(params, frames, cfg: ModelConfig):
    """frames: (B, enc_len, D) stub embeddings -> encoder states."""
    s = frames.shape[1]
    x = frames.astype(cfg.dtype) + L.sinusoidal(jnp.arange(s), cfg.d_model).astype(cfg.dtype)
    positions = jnp.arange(s)

    def layer(x, lp):
        xn = T.norm(cfg, lp["ln1"], x)
        q, k, v = T.qkv(lp["attn"], xn, cfg, positions, rope=False)
        o = attn.blockwise_attention(q, k, v, causal=False)
        h = x + o.reshape(x.shape[0], s, -1) @ lp["attn"]["wo"]
        h = h + L.mlp(lp["mlp"], T.norm(cfg, lp["ln2"], h), "gelu")
        return h, None

    body = jax.checkpoint(layer) if cfg.remat else layer
    x, _ = lax.scan(body, x, params["enc_layers"])
    return T.norm(cfg, params["enc_ln_f"], x)


def _cross_kv(lp, enc, cfg):
    b, se, _ = enc.shape
    hk, dh = cfg.n_kv_heads, cfg.head_dim
    k = (enc @ lp["xattn"]["wk"]).reshape(b, se, hk, dh)
    v = (enc @ lp["xattn"]["wv"]).reshape(b, se, hk, dh)
    return k, v


def _decoder(params, tokens, enc, cfg: ModelConfig, collect_cache: bool = False):
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    x = x + L.sinusoidal(jnp.arange(s), cfg.d_model).astype(cfg.dtype)
    positions = jnp.arange(s)

    def layer(x, lp):
        xn = T.norm(cfg, lp["ln1"], x)
        q, k, v = T.qkv(lp["attn"], xn, cfg, positions, rope=False)
        o = attn.blockwise_attention(q, k, v, causal=True)
        h = x + o.reshape(b, s, -1) @ lp["attn"]["wo"]
        # cross attention
        hn = T.norm(cfg, lp["ln_x"], h)
        qx = (hn @ lp["xattn"]["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
        kx, vx = _cross_kv(lp, enc, cfg)
        ox = attn.blockwise_attention(qx, kx, vx, causal=False)
        h = h + ox.reshape(b, s, -1) @ lp["xattn"]["wo"]
        h = h + L.mlp(lp["mlp"], T.norm(cfg, lp["ln2"], h), "gelu")
        return h, (k, v, kx, vx) if collect_cache else None

    if collect_cache:
        x, caches = lax.scan(layer, x, params["dec_layers"])
    else:
        body = jax.checkpoint(layer) if cfg.remat else layer
        x, caches = lax.scan(body, x, params["dec_layers"])
    return T.norm(cfg, params["ln_f"], x), caches


def loss_fn(params, batch, cfg: ModelConfig):
    enc = encode(params, batch["frames"], cfg)
    x, _ = _decoder(params, batch["tokens"], enc, cfg)
    logits = L.lm_logits(params["embed"], x, cfg.vocab)
    return L.softmax_xent(logits, batch["labels"])


def init_cache_specs(cfg: ModelConfig, batch: int, seq_len: int):
    hk, dh = cfg.n_kv_heads, cfg.head_dim
    s = T.cache_len(cfg, seq_len)
    kv = ParamSpec((cfg.n_layers, batch, s, hk, dh),
                   ("layers", None, None, "kv_heads", None), "zeros", cfg.dtype)
    xkv = ParamSpec((cfg.n_layers, batch, cfg.enc_len, hk, dh),
                    ("layers", None, None, "kv_heads", None), "zeros", cfg.dtype)
    return {"k": kv, "v": kv, "xk": xkv, "xv": xkv}


def prefill(params, batch, cfg: ModelConfig):
    enc = encode(params, batch["frames"], cfg)
    x, (k, v, kx, vx) = _decoder(params, batch["tokens"], enc, cfg, collect_cache=True)
    logits = L.lm_logits(params["embed"], x[:, -1:], cfg.vocab)
    return logits, {"k": k, "v": v, "xk": kx, "xv": vx}


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    b = tokens.shape[0]
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    x = x + L.sinusoidal(pos[:, None], cfg.d_model).astype(cfg.dtype)
    bidx = jnp.arange(b)
    s_cache = cache["k"].shape[2]
    widx = pos % s_cache

    def layer(x, xs):
        lp, kc, vc, kx, vx = xs
        xn = T.norm(cfg, lp["ln1"], x)
        q, k, v = T.qkv(lp["attn"], xn, cfg, pos[:, None], rope=False)
        kc = kc.at[bidx, widx].set(k[:, 0])
        vc = vc.at[bidx, widx].set(v[:, 0])
        o = attn.decode_attention(q, kc, vc, jnp.minimum(pos + 1, s_cache))
        h = x + o.reshape(b, 1, -1) @ lp["attn"]["wo"]
        hn = T.norm(cfg, lp["ln_x"], h)
        qx = (hn @ lp["xattn"]["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
        ox = attn.decode_attention(qx, kx, vx, jnp.full((b,), kx.shape[1]))
        h = h + ox.reshape(b, 1, -1) @ lp["xattn"]["wo"]
        h = h + L.mlp(lp["mlp"], T.norm(cfg, lp["ln2"], h), "gelu")
        return h, (kc, vc)

    x, (ks, vs) = lax.scan(
        layer, x, (params["dec_layers"], cache["k"], cache["v"], cache["xk"], cache["xv"])
    )
    x = T.norm(cfg, params["ln_f"], x)
    logits = L.lm_logits(params["embed"], x, cfg.vocab)
    return logits, {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"]}
