"""Zamba2-style hybrid: Mamba2 backbone with a SHARED attention+MLP block
applied every ``attn_every`` layers (arXiv:2411.15242).

Long-context (long_500k) runs with sliding-window attention on the shared
block (cfg.window), so the whole model stays sub-quadratic: Mamba2 state is
O(1), attention cost is O(window) per token.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import ssm
from repro.models import transformer as T
from repro.models.base import ParamSpec


def n_apps(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.attn_every if cfg.attn_every else 0


def specs(cfg: ModelConfig) -> dict:
    s = {
        "embed": L.embedding_specs(cfg.vocab, cfg.d_model),
        "mamba_layers": T.stack_specs(cfg.n_layers, ssm.mamba2_specs(cfg)),
        "ln_f": T.norm_specs(cfg),
    }
    if cfg.attn_every:
        s["shared"] = {
            "ln1": T.norm_specs(cfg),
            "attn": T.attn_specs(cfg),
            "ln2": T.norm_specs(cfg),
            "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff, gated=True),
        }
    return s


def init_cache_specs(cfg: ModelConfig, batch: int, seq_len: int):
    out = {"mamba": T.stack_specs(cfg.n_layers, ssm.mamba2_state_specs(cfg, batch))}
    if cfg.attn_every:
        w = T.cache_len(cfg, seq_len)
        hk, dh = cfg.n_kv_heads, cfg.head_dim
        kv = ParamSpec((n_apps(cfg), batch, w, hk, dh),
                       (None, None, None, "kv_heads", None), "zeros", cfg.dtype)
        out.update({"k": kv, "v": kv})
    return out


def _segments(cfg: ModelConfig):
    """(start, length, has_attn) per segment: attn fires after each full
    ``attn_every`` mamba layers; a shorter tail has no attn."""
    k = cfg.attn_every or cfg.n_layers
    segs = []
    i = 0
    while i < cfg.n_layers:
        ln = min(k, cfg.n_layers - i)
        segs.append((i, ln, bool(cfg.attn_every) and ln == k))
        i += ln
    return segs


def _slice_tree(tree, start, length):
    return jax.tree_util.tree_map(lambda a: a[start : start + length], tree)


def _mamba_scan(params_slice, x, cfg, states_slice):
    def body(x, xs):
        lp, st = xs
        y, st2 = ssm.mamba2_apply(lp, x, cfg, st)
        return x + y, st2

    return lax.scan(body, x, (params_slice, states_slice))


def _zeros_states(cfg, batch, length):
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        T.stack_specs(length, ssm.mamba2_state_specs(cfg, batch)),
        is_leaf=lambda z: hasattr(z, "init"),
    )


def forward(params, batch, cfg: ModelConfig):
    x = L.embed(params["embed"], batch["tokens"]).astype(cfg.dtype)
    positions = jnp.arange(x.shape[1])
    b = x.shape[0]
    for start, length, has_attn in _segments(cfg):
        x, _ = _mamba_scan(_slice_tree(params["mamba_layers"], start, length),
                           x, cfg, _zeros_states(cfg, b, length))
        if has_attn:
            sp = params["shared"]
            x = x + T.attn_block(sp["attn"], T.norm(cfg, sp["ln1"], x), cfg,
                                 positions, window=cfg.window)
            x = x + L.mlp(sp["mlp"], T.norm(cfg, sp["ln2"], x), cfg.act)
    return T.norm(cfg, params["ln_f"], x)


def loss_fn(params, batch, cfg: ModelConfig):
    x = forward(params, batch, cfg)
    logits = L.lm_logits(params["embed"], x, cfg.vocab)
    return L.softmax_xent(logits, batch["labels"])


def prefill(params, batch, cfg: ModelConfig):
    x = L.embed(params["embed"], batch["tokens"]).astype(cfg.dtype)
    positions = jnp.arange(x.shape[1])
    b, s = x.shape[:2]
    w = T.cache_len(cfg, s)
    m_states, ks, vs = [], [], []
    app = 0
    for start, length, has_attn in _segments(cfg):
        x, st = _mamba_scan(_slice_tree(params["mamba_layers"], start, length),
                            x, cfg, _zeros_states(cfg, b, length))
        m_states.append(st)
        if has_attn:
            sp = params["shared"]
            xn = T.norm(cfg, sp["ln1"], x)
            q, k, v = T.qkv(sp["attn"], xn, cfg, positions)
            o = attn.blockwise_attention(q, k, v, causal=True, window=cfg.window)
            x = x + o.reshape(b, s, -1) @ sp["attn"]["wo"]
            x = x + L.mlp(sp["mlp"], T.norm(cfg, sp["ln2"], x), cfg.act)
            ks.append(k[:, -w:])
            vs.append(v[:, -w:])
            app += 1
    x = T.norm(cfg, params["ln_f"], x)
    logits = L.lm_logits(params["embed"], x[:, -1:], cfg.vocab)
    cache = {"mamba": jax.tree_util.tree_map(lambda *xs: jnp.concatenate(xs, 0), *m_states)}
    if ks:
        cache["k"] = jnp.stack(ks)
        cache["v"] = jnp.stack(vs)
    return logits, cache


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    b = tokens.shape[0]
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    bidx = jnp.arange(b)
    new_m, new_k, new_v = [], [], []
    app = 0
    for start, length, has_attn in _segments(cfg):
        x, st = _mamba_scan(_slice_tree(params["mamba_layers"], start, length),
                            x, cfg, _slice_tree(cache["mamba"], start, length))
        new_m.append(st)
        if has_attn:
            sp = params["shared"]
            kc, vc = cache["k"][app], cache["v"][app]
            s_cache = kc.shape[1]
            widx = pos % s_cache
            xn = T.norm(cfg, sp["ln1"], x)
            q, k, v = T.qkv(sp["attn"], xn, cfg, pos[:, None])
            kc = kc.at[bidx, widx].set(k[:, 0])
            vc = vc.at[bidx, widx].set(v[:, 0])
            o = attn.decode_attention(q, kc, vc, jnp.minimum(pos + 1, s_cache))
            x = x + o.reshape(b, 1, -1) @ sp["attn"]["wo"]
            x = x + L.mlp(sp["mlp"], T.norm(cfg, sp["ln2"], x), cfg.act)
            new_k.append(kc)
            new_v.append(vc)
            app += 1
    x = T.norm(cfg, params["ln_f"], x)
    cache_out = {"mamba": jax.tree_util.tree_map(lambda *xs: jnp.concatenate(xs, 0), *new_m)}
    if new_k:
        cache_out["k"] = jnp.stack(new_k)
        cache_out["v"] = jnp.stack(new_v)
    return L.lm_logits(params["embed"], x, cfg.vocab), cache_out
