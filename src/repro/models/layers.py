"""Shared layer primitives: norms, rotary embeddings, MLPs, embeddings.

All functions are pure; parameters come in as dict pytrees produced by the
matching ``*_specs`` functions (see base.ParamSpec).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.base import ParamSpec


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm_specs(d: int) -> dict:
    return {"scale": ParamSpec((d,), ("embed",), init="ones")}


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * p["scale"]


def layernorm_specs(d: int) -> dict:
    return {
        "scale": ParamSpec((d,), ("embed",), init="ones"),
        "bias": ParamSpec((d,), ("embed",), init="zeros"),
    }


def layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(dt) * p["scale"] + p["bias"]


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------
def rope_freqs(d_head: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : dh // 2], x[..., dh // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal(positions, d: int):
    half = d // 2
    freqs = np.exp(-np.log(10000.0) * np.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def mlp_specs(d: int, f: int, gated: bool = True) -> dict:
    s = {
        "w_in": ParamSpec((d, f), ("embed", "ff"), init="scaled"),
        "w_out": ParamSpec((f, d), ("ff", "embed"), init="scaled"),
    }
    if gated:
        s["w_gate"] = ParamSpec((d, f), ("embed", "ff"), init="scaled")
    return s


def mlp(p, x, act: str = "silu"):
    h = x @ p["w_in"]
    if "w_gate" in p:
        g = x @ p["w_gate"]
        h = h * (jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g))
    else:
        h = jax.nn.silu(h) if act == "silu" else jax.nn.gelu(h)
    return h @ p["w_out"]


# ---------------------------------------------------------------------------
# Embedding / LM head (padded vocab for clean vocab-parallel sharding)
# ---------------------------------------------------------------------------
def padded_vocab(vocab: int, multiple: int = 256) -> int:
    return -(-vocab // multiple) * multiple


def embedding_specs(vocab: int, d: int) -> dict:
    return {"table": ParamSpec((padded_vocab(vocab), d), ("vocab", "embed"))}


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def lm_logits(p, x, true_vocab: int):
    """Tied-embedding head; padded tail masked to -inf for the loss."""
    logits = x @ p["table"].T
    pad = logits.shape[-1] - true_vocab
    if pad:
        mask = jnp.concatenate(
            [jnp.zeros((true_vocab,), logits.dtype), jnp.full((pad,), -1e9, logits.dtype)]
        )
        logits = logits + mask
    return logits


def tied_xent_chunked(embed_params, x, labels, true_vocab: int, chunk: int):
    """Sequence-chunked tied-embedding cross-entropy (§Perf iteration 2).

    The naive path materializes (B, S, V) f32 logits (+ their gradient) —
    at 4k x 32k-vocab that alone is ~2x 8.4 GiB per device. Scanning over
    sequence chunks with rematerialization caps the live logits at
    (B, chunk, V); the backward pass recomputes each chunk's logits.
    """
    b, s, d = x.shape
    n = s // chunk
    assert n * chunk == s, (s, chunk)
    xs = x.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, xsl):
        xc, lc = xsl
        logits = lm_logits(embed_params, xc, true_vocab).astype(jnp.float32)
        mask = lc != -1
        lab = jnp.maximum(lc, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        loss, cnt = carry
        return (loss + ((lse - ll) * mask).sum(), cnt + mask.sum()), None

    (loss, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.int32(0)), (xs, ls))
    return loss / jnp.maximum(cnt, 1)


def softmax_xent(logits, labels, ignore: int = -1):
    """Token-mean cross entropy in f32; ``ignore`` labels are masked."""
    logits = logits.astype(jnp.float32)
    mask = labels != ignore
    lab = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
    loss = (lse - ll) * mask
    return loss.sum() / jnp.maximum(mask.sum(), 1)
