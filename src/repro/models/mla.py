"""Multi-head Latent Attention (DeepSeek-V2/V3).

Train/prefill run the explicit (decompressed) form; decode runs the
*absorbed* form — q is projected into the KV latent space so attention
contracts directly against the cached compressed latents. The cache is
(c_kv, k_rope): kv_lora_rank + rope_head_dim floats per position instead of
2 * H * d_head — this latent page is exactly what the RARO KV tiers manage
for deepseek-v3 (DESIGN.md §5).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models.base import ParamSpec


def mla_specs(cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    ql, kl = cfg.q_lora_rank, cfg.kv_lora_rank
    return {
        "wq_a": ParamSpec((d, ql), ("embed", None), "scaled"),
        "q_ln": L.rmsnorm_specs(ql),
        "wq_b": ParamSpec((ql, h * (dn + dr)), (None, "heads"), "scaled"),
        "wkv_a": ParamSpec((d, kl + dr), ("embed", None), "scaled"),
        "kv_ln": L.rmsnorm_specs(kl),
        "wkv_b": ParamSpec((kl, h * (dn + dv)), (None, "heads"), "scaled"),
        "wo": ParamSpec((h * dv, d), ("heads", "embed"), "scaled"),
    }


def _project_q(p, x, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr = cfg.nope_head_dim, cfg.rope_head_dim
    q = L.rmsnorm(p["q_ln"], x @ p["wq_a"]) @ p["wq_b"]
    q = q.reshape(b, s, h, dn + dr)
    qn, qr = q[..., :dn], q[..., dn:]
    qr = L.apply_rope(qr, positions, cfg.rope_theta)
    return qn, qr


def _project_kv_latent(p, x, cfg: ModelConfig, positions):
    """x -> (c_kv normalized (B,S,KL), k_rope roped (B,S,DR))."""
    kl, dr = cfg.kv_lora_rank, cfg.rope_head_dim
    kv_a = x @ p["wkv_a"]
    ckv = L.rmsnorm(p["kv_ln"], kv_a[..., :kl])
    kr = kv_a[..., kl:]
    kr = L.apply_rope(kr[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return ckv, kr


def mla_attention(p, x, cfg: ModelConfig, positions, return_cache: bool = False):
    """Explicit-form MLA for train/prefill. Returns out [, (c_kv, k_rope)]."""
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim

    qn, qr = _project_q(p, x, cfg, positions)
    ckv, kr = _project_kv_latent(p, x, cfg, positions)

    kv = (ckv @ p["wkv_b"]).reshape(b, s, h, dn + dv)
    kn, v = kv[..., :dn], kv[..., dn:]
    k = jnp.concatenate([kn, jnp.broadcast_to(kr[:, :, None, :], (b, s, h, dr))], axis=-1)
    q = jnp.concatenate([qn, qr], axis=-1)

    o = attn.blockwise_attention(q, k, v, causal=True, window=cfg.window)
    out = o.reshape(b, s, h * dv) @ p["wo"]
    if return_cache:
        return out, (ckv, kr)
    return out


def mla_decode(p, x, cfg: ModelConfig, pos, ckv_cache, kr_cache):
    """Absorbed-form single-token decode.

    x: (B,1,D); caches: (B,S,KL) and (B,S,DR). Returns (out, caches).
    """
    b = x.shape[0]
    h = cfg.n_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    kl = cfg.kv_lora_rank
    s_cache = ckv_cache.shape[1]
    bidx = jnp.arange(b)
    widx = pos % s_cache

    qn, qr = _project_q(p, x, cfg, pos[:, None])
    ckv_new, kr_new = _project_kv_latent(p, x, cfg, pos[:, None])
    ckv_cache = ckv_cache.at[bidx, widx].set(ckv_new[:, 0])
    kr_cache = kr_cache.at[bidx, widx].set(kr_new[:, 0])

    w_b = p["wkv_b"].reshape(kl, h, dn + dv)
    w_uk, w_uv = w_b[..., :dn], w_b[..., dn:]

    q_lat = jnp.einsum("bqhd,lhd->bqhl", qn.astype(jnp.float32), w_uk.astype(jnp.float32))
    scores = jnp.einsum("bqhl,bkl->bqhk", q_lat, ckv_cache.astype(jnp.float32))
    scores += jnp.einsum("bqhd,bkd->bqhk", qr.astype(jnp.float32), kr_cache.astype(jnp.float32))
    scores *= (dn + dr) ** -0.5

    k_pos = jnp.arange(s_cache)
    mask = k_pos[None, :] < jnp.minimum(pos + 1, s_cache)[:, None]
    scores = jnp.where(mask[:, None, None, :], scores, attn.NEG_INF)
    probs = jnp.exp(scores - scores.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)

    ctx = jnp.einsum("bqhk,bkl->bqhl", probs, ckv_cache.astype(jnp.float32))
    o = jnp.einsum("bqhl,lhd->bqhd", ctx, w_uv.astype(jnp.float32)).astype(x.dtype)
    out = o.reshape(b, 1, h * dv) @ p["wo"]
    return out, ckv_cache, kr_cache
