"""Mixture-of-Experts transformers: granite-moe (top-8 of 40, GQA) and
deepseek-v3 (MLA + 1 shared + 256 routed top-8 + MTP).

Dispatch is sort-based with capacity (MegaBlocks-style dense buffers):
tokens are argsorted by expert, placed into an (E, C, D) buffer (capacity
drop), run through vmapped expert FFNs as grouped GEMMs, and combined by
router weight. With experts sharded over "model" this lowers to the
canonical all-to-all dispatch pattern.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import mla as mla_mod
from repro.models import transformer as T
from repro.models.base import ParamSpec


def moe_specs(cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    s = {
        "router": ParamSpec((d, e), ("embed", None), "scaled", jnp.float32),
        "w_in": ParamSpec((e, d, f), ("experts", "embed", "moe_ff"), "scaled"),
        "w_gate": ParamSpec((e, d, f), ("experts", "embed", "moe_ff"), "scaled"),
        "w_out": ParamSpec((e, f, d), ("experts", "moe_ff", "embed"), "scaled"),
    }
    if cfg.n_shared_experts:
        s["shared"] = L.mlp_specs(d, cfg.moe_d_ff * cfg.n_shared_experts)
    return s


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor) + 1
    return -(-c // 8) * 8  # pad for tiling


# ---------------------------------------------------------------------------
# Expert-parallel dispatch via shard_map + all_to_all (§Perf iteration 3b).
#
# The jit-level scatter dispatch below lowers through GSPMD to a replicated
# (T*K, D) scatter + all-reduce — measured at 240 GB/chip/layer on
# deepseek-v3 train_4k. The shard_map version routes each token exactly
# once: tokens are split over the model axis, every chip quantizes its own
# routing, packs a fixed-capacity (tp, cap_send, D) send buffer, and a
# single all_to_all over "model" delivers tokens to their expert shard
# (wire = cap_send * D * 2B per chip instead of the full token matrix).
# ---------------------------------------------------------------------------
def moe_apply_ep(p, x, cfg: ModelConfig, mesh):
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    tp = mesh.shape["model"]
    dp_axes = tuple(a for a in mesh.axis_names if a != "model")
    e_loc = cfg.n_experts // tp

    def local(p_loc, x_loc):
        # x_loc: (b_loc, s_loc, D); p_loc experts: (e_loc, D, F)
        b, s, d = x_loc.shape
        n = b * s
        k = cfg.top_k
        xf = x_loc.reshape(n, d)
        logits = (xf.astype(jnp.float32) @ p_loc["router"]).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = lax.top_k(probs, k)  # (n, k) global expert ids
        w = w / w.sum(-1, keepdims=True)

        me = jax.lax.pmean(probs.mean(0), mesh.axis_names)
        ce = jnp.zeros((cfg.n_experts,), jnp.float32).at[idx.reshape(-1)].add(
            w.reshape(-1)) / n
        ce = jax.lax.pmean(ce, mesh.axis_names)
        aux = cfg.aux_loss_coef * cfg.n_experts * jnp.sum(me * ce)

        # pack send buffer: one row group per destination expert shard
        cap_send = -(-int(n * k * cfg.capacity_factor) // tp)
        cap_send = -(-cap_send // 8) * 8
        dest = idx.reshape(-1) // e_loc  # (n*k,) destination shard
        order = jnp.argsort(dest)
        sorted_dest = dest[order]
        seg = jnp.searchsorted(sorted_dest, jnp.arange(tp))
        pos = jnp.arange(n * k) - seg[sorted_dest]
        keep = pos < cap_send
        slot = jnp.where(keep, sorted_dest * cap_send + pos, tp * cap_send)
        tok = order // k
        send = jnp.zeros((tp * cap_send, d), x_loc.dtype).at[slot].set(
            xf[tok], mode="drop")
        send_eid = jnp.full((tp * cap_send,), -1, jnp.int32).at[slot].set(
            idx.reshape(-1)[order] % e_loc, mode="drop")

        recv = lax.all_to_all(send.reshape(tp, cap_send, d), "model", 0, 0,
                              tiled=False)
        recv_eid = lax.all_to_all(send_eid.reshape(tp, cap_send), "model", 0, 0,
                                  tiled=False)
        rx = recv.reshape(tp * cap_send, d)
        re = recv_eid.reshape(tp * cap_send)

        # local grouped GEMMs over my e_loc experts, capacity per expert
        cap_e = -(-tp * cap_send // e_loc)
        cap_e = -(-cap_e // 8) * 8
        order2 = jnp.argsort(jnp.where(re >= 0, re, e_loc))
        se = jnp.where(re[order2] >= 0, re[order2], e_loc)
        seg2 = jnp.searchsorted(se, jnp.arange(e_loc))
        pos2 = jnp.arange(tp * cap_send) - seg2[jnp.minimum(se, e_loc - 1)]
        keep2 = (se < e_loc) & (pos2 < cap_e)
        slot2 = jnp.where(keep2, se * cap_e + pos2, e_loc * cap_e)
        buf = jnp.zeros((e_loc * cap_e, d), x_loc.dtype).at[slot2].set(
            rx[order2], mode="drop")
        hb = buf.reshape(e_loc, cap_e, d)
        h = jnp.einsum("ecd,edf->ecf", hb, p_loc["w_in"])
        g = jnp.einsum("ecd,edf->ecf", hb, p_loc["w_gate"])
        h = (h * jax.nn.silu(g)).astype(x_loc.dtype)
        yb = jnp.einsum("ecf,efd->ecd", h, p_loc["w_out"]).reshape(e_loc * cap_e, d)

        # un-sort back to recv order, return through all_to_all
        out_rx = jnp.zeros((tp * cap_send, d), jnp.float32)
        out_rx = out_rx.at[jnp.where(keep2, order2, tp * cap_send)].set(
            yb[jnp.minimum(slot2, e_loc * cap_e - 1)].astype(jnp.float32),
            mode="drop")
        back = lax.all_to_all(out_rx.reshape(tp, cap_send, d), "model", 0, 0,
                              tiled=False).reshape(tp * cap_send, d)

        # combine: weight each assignment and scatter-add to its token
        per_assign = back[jnp.minimum(slot, tp * cap_send - 1)]
        per_assign = jnp.where(keep[:, None], per_assign, 0)
        w_sorted = w.reshape(-1)[order]
        y = jnp.zeros((n, d), jnp.float32).at[
            jnp.where(keep, tok, n)].add(per_assign * w_sorted[:, None], mode="drop")

        if cfg.n_shared_experts:
            y = y + L.mlp(p_loc["shared"], xf, cfg.act).astype(jnp.float32)
        return y.reshape(b, s, d).astype(x_loc.dtype), aux

    pspec_params = {
        "router": P(None, None),
        "w_in": P("model", None, None),
        "w_gate": P("model", None, None),
        "w_out": P("model", None, None),
    }
    if cfg.n_shared_experts:
        pspec_params["shared"] = jax.tree_util.tree_map(
            lambda _: P(None, None), p["shared"])
    x_spec = P(dp_axes, "model", None)
    fn = shard_map(local, mesh=mesh, in_specs=(pspec_params, x_spec),
                   out_specs=(x_spec, P()))
    return fn(p, x)


def moe_apply(p, x, cfg: ModelConfig):
    """x: (B, S, D) -> (out, aux_loss)."""
    b, s, d = x.shape
    n = b * s
    k = cfg.top_k
    e = cfg.n_experts
    xf = x.reshape(n, d)

    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (N, E)
    w, idx = lax.top_k(probs, k)  # (N, K)
    w = w / w.sum(-1, keepdims=True)

    # Switch-style load-balance auxiliary loss.
    me = probs.mean(0)
    ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(w.reshape(-1)) / n
    aux = cfg.aux_loss_coef * e * jnp.sum(me * ce)

    # ---- sort-based dispatch with capacity ----
    cap = capacity(cfg, n)
    flat_e = idx.reshape(-1)  # (N*K,)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e))
    pos = jnp.arange(n * k) - seg_start[sorted_e]
    keep = pos < cap
    slot = jnp.where(keep, sorted_e * cap + pos, e * cap)  # OOB -> dropped
    tok = order // k

    buf = jnp.zeros((e * cap, d), x.dtype).at[slot].set(xf[tok], mode="drop")
    hb = buf.reshape(e, cap, d)
    h = jnp.einsum("ecd,edf->ecf", hb, p["w_in"])
    g = jnp.einsum("ecd,edf->ecf", hb, p["w_gate"])
    h = (h * jax.nn.silu(g)).astype(x.dtype)
    yb = jnp.einsum("ecf,efd->ecd", h, p["w_out"]).reshape(e * cap, d)

    # ---- combine ----
    per_assign = jnp.where(keep[:, None], yb[jnp.minimum(slot, e * cap - 1)], 0)
    w_sorted = w.reshape(-1)[order]
    y = jnp.zeros((n, d), jnp.float32).at[tok].add(
        per_assign.astype(jnp.float32) * w_sorted[:, None]
    )

    if cfg.n_shared_experts:
        y = y + L.mlp(p["shared"], xf, cfg.act).astype(jnp.float32)
    return y.reshape(b, s, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Full MoE decoder model (granite / deepseek-v3)
# ---------------------------------------------------------------------------
def _ambient_mesh():
    """Mesh for shard_map EP dispatch, if we are under jax.set_mesh with a
    real model axis; None -> fall back to the jit-level dispatch."""
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and "model" in m.axis_names and m.shape["model"] > 1:
            return m
    except Exception:
        pass
    return None


def _moe_ffn(cfg: ModelConfig, p, xn):
    """Dispatch selector: shard_map EP when enabled + applicable."""
    if cfg.moe_hints:
        mesh = _ambient_mesh()
        if (mesh is not None and cfg.n_experts % mesh.shape["model"] == 0
                and xn.shape[1] % mesh.shape["model"] == 0):
            return moe_apply_ep(p, xn, cfg, mesh)
    return moe_apply(p, xn, cfg)


def _attn_specs(cfg: ModelConfig):
    return mla_mod.mla_specs(cfg) if cfg.mla else T.attn_specs(cfg)


def _attn_apply(cfg, p, xn, positions):
    if cfg.mla:
        return mla_mod.mla_attention(p, xn, cfg, positions)
    b, s, _ = xn.shape
    q, k, v = T.qkv(p, xn, cfg, positions)
    o = attn.blockwise_attention(q, k, v, causal=True, window=cfg.window)
    return o.reshape(b, s, -1) @ p["wo"]


def moe_layer_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": T.norm_specs(cfg),
        "attn": _attn_specs(cfg),
        "ln2": T.norm_specs(cfg),
        "moe": moe_specs(cfg),
    }


def dense_layer_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": T.norm_specs(cfg),
        "attn": _attn_specs(cfg),
        "ln2": T.norm_specs(cfg),
        "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff, gated=True),
    }


def specs(cfg: ModelConfig) -> dict:
    s = {
        "embed": L.embedding_specs(cfg.vocab, cfg.d_model),
        "moe_layers": T.stack_specs(cfg.n_layers - cfg.first_k_dense, moe_layer_specs(cfg)),
        "ln_f": T.norm_specs(cfg),
    }
    if cfg.first_k_dense:
        s["dense_layers"] = T.stack_specs(cfg.first_k_dense, dense_layer_specs(cfg))
    if cfg.mtp_depth:
        s["mtp"] = {
            "proj": ParamSpec((2 * cfg.d_model, cfg.d_model), ("embed", "embed"), "scaled"),
            "block": dense_layer_specs(cfg),
            "ln": T.norm_specs(cfg),
        }
    return s


def _dense_layer(cfg, lp, x, positions):
    h = x + _attn_apply(cfg, lp["attn"], T.norm(cfg, lp["ln1"], x), positions)
    return h + L.mlp(lp["mlp"], T.norm(cfg, lp["ln2"], h), cfg.act)


def forward(params, batch, cfg: ModelConfig):
    """Returns (hidden (B,S,D), aux_loss)."""
    x = L.embed(params["embed"], batch["tokens"]).astype(cfg.dtype)
    positions = jnp.arange(x.shape[1])

    if cfg.first_k_dense:
        def dense_body(x, lp):
            return _dense_layer(cfg, lp, x, positions), None
        dbody = jax.checkpoint(dense_body) if cfg.remat else dense_body
        x, _ = lax.scan(dbody, x, params["dense_layers"])

    def moe_body(carry, lp):
        x, aux = carry
        h = x + _attn_apply(cfg, lp["attn"], T.norm(cfg, lp["ln1"], x), positions)
        y, a = _moe_ffn(cfg, lp["moe"], T.norm(cfg, lp["ln2"], h))
        return (h + y, aux + a), None

    mbody = jax.checkpoint(moe_body) if cfg.remat else moe_body
    (x, aux), _ = lax.scan(mbody, (x, jnp.float32(0.0)), params["moe_layers"])
    return T.norm(cfg, params["ln_f"], x), aux


def loss_fn(params, batch, cfg: ModelConfig):
    x, aux = forward(params, batch, cfg)
    logits = L.lm_logits(params["embed"], x, cfg.vocab)
    loss = L.softmax_xent(logits, batch["labels"])
    if cfg.mtp_depth:
        # DeepSeek-V3 MTP (depth 1): predict token t+2 from [h_t ; emb(t+1)].
        nxt = batch["labels"]  # token at t+1
        emb_next = L.embed(params["embed"], jnp.maximum(nxt, 0)).astype(cfg.dtype)
        h2 = jnp.concatenate([x, emb_next], axis=-1) @ params["mtp"]["proj"]
        h2 = _dense_layer(cfg, params["mtp"]["block"], h2, jnp.arange(x.shape[1]))
        h2 = T.norm(cfg, params["mtp"]["ln"], h2)
        logits2 = L.lm_logits(params["embed"], h2[:, :-1], cfg.vocab)
        mtp_labels = batch["labels"][:, 1:]  # token at t+2
        loss = loss + cfg.mtp_loss_coef * L.softmax_xent(logits2, mtp_labels)
    return loss + aux


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------
def init_cache_specs(cfg: ModelConfig, batch: int, seq_len: int):
    s = T.cache_len(cfg, seq_len)
    if cfg.mla:
        n_moe = cfg.n_layers - cfg.first_k_dense
        out = {
            "moe_ckv": ParamSpec((n_moe, batch, s, cfg.kv_lora_rank),
                                 ("layers", None, None, None), "zeros", cfg.dtype),
            "moe_krope": ParamSpec((n_moe, batch, s, cfg.rope_head_dim),
                                   ("layers", None, None, None), "zeros", cfg.dtype),
        }
        if cfg.first_k_dense:
            out["dense_ckv"] = ParamSpec((cfg.first_k_dense, batch, s, cfg.kv_lora_rank),
                                         ("layers", None, None, None), "zeros", cfg.dtype)
            out["dense_krope"] = ParamSpec((cfg.first_k_dense, batch, s, cfg.rope_head_dim),
                                           ("layers", None, None, None), "zeros", cfg.dtype)
        return out
    hk, dh = cfg.n_kv_heads, cfg.head_dim
    n_moe = cfg.n_layers - cfg.first_k_dense
    kv = ParamSpec((n_moe, batch, s, hk, dh), ("layers", None, None, "kv_heads", None),
                   "zeros", cfg.dtype)
    out = {"moe_k": kv, "moe_v": kv}
    if cfg.first_k_dense:
        kvd = ParamSpec((cfg.first_k_dense, batch, s, hk, dh),
                        ("layers", None, None, "kv_heads", None), "zeros", cfg.dtype)
        out.update({"dense_k": kvd, "dense_v": kvd})
    return out


def prefill(params, batch, cfg: ModelConfig):
    x = L.embed(params["embed"], batch["tokens"]).astype(cfg.dtype)
    positions = jnp.arange(x.shape[1])
    cache = {}

    if cfg.first_k_dense:
        def dbody(x, lp):
            if cfg.mla:
                xn = T.norm(cfg, lp["ln1"], x)
                o, (ckv, krope) = mla_mod.mla_attention(lp["attn"], xn, cfg, positions,
                                                        return_cache=True)
                h = x + o
                kv = (ckv, krope)
            else:
                xn = T.norm(cfg, lp["ln1"], x)
                q, k, v = T.qkv(lp["attn"], xn, cfg, positions)
                o = attn.blockwise_attention(q, k, v, causal=True, window=cfg.window)
                h = x + o.reshape(x.shape[0], x.shape[1], -1) @ lp["attn"]["wo"]
                kv = (k, v)
            h = h + L.mlp(lp["mlp"], T.norm(cfg, lp["ln2"], h), cfg.act)
            return h, kv

        x, (c1, c2) = lax.scan(dbody, x, params["dense_layers"])
        cache.update({"dense_ckv" if cfg.mla else "dense_k": c1,
                      "dense_krope" if cfg.mla else "dense_v": c2})

    def mbody(carry, lp):
        x, aux = carry
        xn = T.norm(cfg, lp["ln1"], x)
        if cfg.mla:
            o, (c1, c2) = mla_mod.mla_attention(lp["attn"], xn, cfg, positions,
                                                return_cache=True)
            h = x + o
        else:
            q, k, v = T.qkv(lp["attn"], xn, cfg, positions)
            o = attn.blockwise_attention(q, k, v, causal=True, window=cfg.window)
            h = x + o.reshape(x.shape[0], x.shape[1], -1) @ lp["attn"]["wo"]
            c1, c2 = k, v
        y, a = _moe_ffn(cfg, lp["moe"], T.norm(cfg, lp["ln2"], h))
        return (h + y, aux + a), (c1, c2)

    (x, _), (c1, c2) = lax.scan(mbody, (x, jnp.float32(0.0)), params["moe_layers"])
    cache.update({"moe_ckv" if cfg.mla else "moe_k": c1,
                  "moe_krope" if cfg.mla else "moe_v": c2})
    x = T.norm(cfg, params["ln_f"], x)
    logits = L.lm_logits(params["embed"], x[:, -1:], cfg.vocab)
    w = T.cache_len(cfg, batch["tokens"].shape[1])
    cache = {k: v[:, :, -w:] for k, v in cache.items()}
    return logits, cache


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    b = tokens.shape[0]
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    bidx = jnp.arange(b)

    def attn_decode(lp, x, c1, c2):
        s_cache = c1.shape[1]
        widx = pos % s_cache
        xn = T.norm(cfg, lp["ln1"], x)
        if cfg.mla:
            o, c1, c2 = mla_mod.mla_decode(lp["attn"], xn, cfg, pos, c1, c2)
        else:
            q, k, v = T.qkv(lp["attn"], xn, cfg, pos[:, None])
            c1 = c1.at[bidx, widx].set(k[:, 0])
            c2 = c2.at[bidx, widx].set(v[:, 0])
            o = attn.decode_attention(q, c1, c2, jnp.minimum(pos + 1, s_cache))
            o = o.reshape(b, 1, -1) @ lp["attn"]["wo"]
        return x + o, c1, c2

    new_cache = dict(cache)
    if cfg.first_k_dense:
        k1 = "dense_ckv" if cfg.mla else "dense_k"
        k2 = "dense_krope" if cfg.mla else "dense_v"

        def dbody(x, xs):
            lp, c1, c2 = xs
            h, c1, c2 = attn_decode(lp, x, c1, c2)
            h = h + L.mlp(lp["mlp"], T.norm(cfg, lp["ln2"], h), cfg.act)
            return h, (c1, c2)

        x, (nc1, nc2) = lax.scan(dbody, x, (params["dense_layers"], cache[k1], cache[k2]))
        new_cache[k1], new_cache[k2] = nc1, nc2

    k1 = "moe_ckv" if cfg.mla else "moe_k"
    k2 = "moe_krope" if cfg.mla else "moe_v"

    def mbody(x, xs):
        lp, c1, c2 = xs
        h, c1, c2 = attn_decode(lp, x, c1, c2)
        y, _ = moe_apply(lp["moe"], T.norm(cfg, lp["ln2"], h), cfg)
        return h + y, (c1, c2)

    x, (nc1, nc2) = lax.scan(mbody, x, (params["moe_layers"], cache[k1], cache[k2]))
    new_cache[k1], new_cache[k2] = nc1, nc2
    x = T.norm(cfg, params["ln_f"], x)
    return L.lm_logits(params["embed"], x, cfg.vocab), new_cache
