"""Model registry: family dispatch + abstract input specs per shape cell."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import base, encdec, hybrid, moe, transformer, xlstm


@dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    specs: Callable
    loss_fn: Callable
    prefill: Callable
    decode_step: Callable
    init_cache_specs: Callable


_FAMILIES = {
    "dense": transformer,
    "vlm": transformer,
    "moe": moe,
    "encdec": encdec,
    "ssm": xlstm,
    "hybrid": hybrid,
}


def get_api(cfg: ModelConfig) -> ModelAPI:
    mod = _FAMILIES[cfg.family]
    return ModelAPI(
        cfg=cfg,
        specs=lambda: mod.specs(cfg),
        loss_fn=lambda p, b: mod.loss_fn(p, b, cfg),
        prefill=lambda p, b: mod.prefill(p, b, cfg),
        decode_step=lambda p, c, t, pos: mod.decode_step(p, c, t, pos, cfg),
        init_cache_specs=lambda batch, seq: mod.init_cache_specs(cfg, batch, seq),
    )


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell —
    weak-type-correct, shardable, zero allocation (dry-run contract)."""
    i32 = jnp.int32
    gb, s = shape.global_batch, shape.seq_len

    if shape.kind in ("train", "prefill"):
        n_txt = s - cfg.n_img_tokens if cfg.family == "vlm" else s
        batch = {"tokens": jax.ShapeDtypeStruct((gb, n_txt), i32)}
        if shape.kind == "train":
            batch["labels"] = jax.ShapeDtypeStruct((gb, n_txt), i32)
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct((gb, cfg.enc_len, cfg.d_model), cfg.dtype)
        if cfg.family == "vlm":
            batch["img_embeds"] = jax.ShapeDtypeStruct((gb, cfg.n_img_tokens, cfg.d_model), cfg.dtype)
        return batch

    # decode: one new token against a seq_len-deep cache
    api = get_api(cfg)
    cache = base.abstract(api.init_cache_specs(gb, s))
    return {
        "tokens": jax.ShapeDtypeStruct((gb, 1), i32),
        "pos": jax.ShapeDtypeStruct((gb,), i32),
        "cache": cache,
    }
