"""Recurrent sequence mixers: xLSTM (mLSTM + sLSTM blocks) and Mamba2.

All recurrences run as lax.scan over the sequence (O(S) state, no
attention) — these are the sub-quadratic archs that serve the long_500k
shape. Decode is a single scan step over carried state; there is no KV
cache, so the RARO tiering technique is inapplicable here (DESIGN.md §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.base import ParamSpec


def _causal_depthwise_conv(x, w, state=None):
    """x: (B,S,C); w: (K,C) depthwise causal. state: (B,K-1,C) carry-in.

    Returns (y (B,S,C), new_state (B,K-1,C))."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(k))
    return y, xp[:, -(k - 1) :] if k > 1 else state


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory block)
# ---------------------------------------------------------------------------
def mlstm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = cfg.expand * d
    h = cfg.n_heads
    return {
        "ln": L.rmsnorm_specs(d),
        "w_up": ParamSpec((d, 2 * di), ("embed", "ff"), "scaled"),
        "conv": ParamSpec((cfg.d_conv, di), ("conv", None), "normal"),
        "wq": ParamSpec((di, di), ("ff", None), "scaled"),
        "wk": ParamSpec((di, di), ("ff", None), "scaled"),
        "wv": ParamSpec((di, di), ("ff", None), "scaled"),
        "w_if": ParamSpec((d, 2 * h), ("embed", None), "scaled", jnp.float32),
        "b_if": ParamSpec((2 * h,), (None,), "zeros", jnp.float32),
        "gn": ParamSpec((di,), ("ff",), "ones"),
        "w_down": ParamSpec((di, d), ("ff", "embed"), "scaled"),
    }


def mlstm_state_specs(cfg: ModelConfig, batch: int) -> dict:
    di = cfg.expand * cfg.d_model
    h = cfg.n_heads
    dh = di // h
    return {
        "C": ParamSpec((batch, h, dh, dh), (None, "heads", None, None), "zeros", jnp.float32),
        "n": ParamSpec((batch, h, dh), (None, "heads", None), "zeros", jnp.float32),
        "m": ParamSpec((batch, h), (None, "heads"), "zeros", jnp.float32),
        "conv": ParamSpec((batch, cfg.d_conv - 1, di), (None, None, "ff"), "zeros", cfg.dtype),
    }


def _mlstm_cell(qkvif, state):
    """One step. q,k,v: (B,H,Dh); i_raw,f_raw: (B,H)."""
    q, k, v, i_raw, f_raw = qkvif
    C, n, m = state
    dh = q.shape[-1]
    log_f = -jax.nn.softplus(-f_raw)  # log sigmoid
    m_new = jnp.maximum(log_f + m, i_raw)
    i_g = jnp.exp(i_raw - m_new)[..., None]
    f_g = jnp.exp(log_f + m - m_new)[..., None]
    k_s = k.astype(jnp.float32) * (dh**-0.5)
    C = f_g[..., None] * C + i_g[..., None] * (v.astype(jnp.float32)[..., :, None] * k_s[..., None, :])
    n = f_g * n + i_g * k_s
    hn = jnp.einsum("bhvk,bhk->bhv", C, q.astype(jnp.float32))
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q.astype(jnp.float32))), 1.0)
    h_out = hn / denom[..., None]
    return h_out, (C, n, m_new)


def mlstm_apply(p, x, cfg: ModelConfig, state=None):
    """x: (B,S,D). Returns (y, new_state)."""
    b, s, d = x.shape
    di = cfg.expand * d
    h = cfg.n_heads
    dh = di // h
    xn = L.rmsnorm(p["ln"], x)
    up = xn @ p["w_up"]
    u, z = up[..., :di], up[..., di:]
    conv_state = None if state is None else state["conv"]
    uc, conv_new = _causal_depthwise_conv(u, p["conv"], conv_state)
    uc = jax.nn.silu(uc)
    q = (uc @ p["wq"]).reshape(b, s, h, dh)
    k = (uc @ p["wk"]).reshape(b, s, h, dh)
    v = (u @ p["wv"]).reshape(b, s, h, dh)
    gates = xn.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    i_raw, f_raw = gates[..., :h], gates[..., h:]

    if state is None:
        C0 = jnp.zeros((b, h, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h, dh), jnp.float32)
        m0 = jnp.zeros((b, h), jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    def step(carry, xs):
        h_out, carry = _mlstm_cell(xs, carry)
        return carry, h_out

    xs = (
        q.transpose(1, 0, 2, 3),
        k.transpose(1, 0, 2, 3),
        v.transpose(1, 0, 2, 3),
        i_raw.transpose(1, 0, 2),
        f_raw.transpose(1, 0, 2),
    )
    (C, n, m), hs = lax.scan(step, (C0, n0, m0), xs)
    hs = hs.transpose(1, 0, 2, 3).reshape(b, s, di).astype(x.dtype)
    # per-head group norm + output gate
    hs = hs.reshape(b, s, h, dh)
    mu = hs.mean(-1, keepdims=True)
    var = jnp.var(hs, axis=-1, keepdims=True)
    hs = ((hs - mu) * lax.rsqrt(var + 1e-6)).reshape(b, s, di) * p["gn"]
    y = (hs * jax.nn.silu(z)) @ p["w_down"]
    new_state = {"C": C, "n": n, "m": m, "conv": conv_new}
    return y, new_state


# ---------------------------------------------------------------------------
# sLSTM (scalar-memory block)
# ---------------------------------------------------------------------------
def slstm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    return {
        "ln": L.rmsnorm_specs(d),
        "w": ParamSpec((d, 4 * d), ("embed", "ff"), "scaled"),
        "r": ParamSpec((h, dh, 4 * dh), ("heads", None, None), "scaled"),
        "b": ParamSpec((4 * d,), (None,), "zeros", jnp.float32),
        "gn": ParamSpec((d,), ("embed",), "ones"),
        "w_down": ParamSpec((d, d), ("embed", "embed"), "scaled"),
    }


def slstm_state_specs(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    return {
        "c": ParamSpec((batch, d), (None, "embed"), "zeros", jnp.float32),
        "n2": ParamSpec((batch, d), (None, "embed"), "zeros", jnp.float32),
        "m2": ParamSpec((batch, d), (None, "embed"), "zeros", jnp.float32),
        "h": ParamSpec((batch, d), (None, "embed"), "zeros", jnp.float32),
    }


def slstm_apply(p, x, cfg: ModelConfig, state=None):
    b, s, d = x.shape
    h_heads = cfg.n_heads
    dh = d // h_heads
    xn = L.rmsnorm(p["ln"], x)
    wx = xn @ p["w"]  # (B,S,4d)

    if state is None:
        c0 = jnp.zeros((b, d), jnp.float32)
        n0 = jnp.zeros((b, d), jnp.float32)
        m0 = jnp.full((b, d), 0.0, jnp.float32)
        h0 = jnp.zeros((b, d), jnp.float32)
    else:
        c0, n0, m0, h0 = state["c"], state["n2"], state["m2"], state["h"]

    def step(carry, wx_t):
        c, n, m, h_prev = carry
        hp = h_prev.reshape(b, h_heads, dh)
        rec = jnp.einsum("bhd,hde->bhe", hp, p["r"].astype(jnp.float32)).reshape(b, 4 * d)
        g = wx_t.astype(jnp.float32) + rec + p["b"]
        i_raw, f_raw, z_raw, o_raw = jnp.split(g, 4, axis=-1)
        log_f = -jax.nn.softplus(-f_raw)
        m_new = jnp.maximum(log_f + m, i_raw)
        i_g = jnp.exp(i_raw - m_new)
        f_g = jnp.exp(log_f + m - m_new)
        c_new = f_g * c + i_g * jnp.tanh(z_raw)
        n_new = f_g * n + i_g
        h_new = jax.nn.sigmoid(o_raw) * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, m_new, h_new), h_new

    (c, n, m, h_last), hs = lax.scan(step, (c0, n0, m0, h0), wx.transpose(1, 0, 2))
    hs = hs.transpose(1, 0, 2).astype(x.dtype)
    mu = hs.reshape(b, s, h_heads, dh).mean(-1, keepdims=True)
    var = jnp.var(hs.reshape(b, s, h_heads, dh), axis=-1, keepdims=True)
    hs = ((hs.reshape(b, s, h_heads, dh) - mu) * lax.rsqrt(var + 1e-6)).reshape(b, s, d)
    y = (hs * p["gn"]) @ p["w_down"]
    return y, {"c": c, "n2": n, "m2": m, "h": h_last}


# ---------------------------------------------------------------------------
# Mamba2 (SSD scalar-A recurrence) — zamba2 backbone
# ---------------------------------------------------------------------------
def mamba2_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = cfg.expand * d
    n = cfg.d_state
    h = max(di // 64, 1)  # P = 64 head channels
    return {
        "ln": L.rmsnorm_specs(d),
        "in_proj": ParamSpec((d, 2 * di + 2 * n + h), ("embed", "ff"), "scaled"),
        "conv": ParamSpec((cfg.d_conv, di + 2 * n), ("conv", None), "normal"),
        "a_log": ParamSpec((h,), (None,), "zeros", jnp.float32),
        "dt_bias": ParamSpec((h,), (None,), "zeros", jnp.float32),
        "d_skip": ParamSpec((h,), (None,), "ones", jnp.float32),
        "gn": ParamSpec((di,), ("ff",), "ones"),
        "out_proj": ParamSpec((di, d), ("ff", "embed"), "scaled"),
    }


def mamba2_state_specs(cfg: ModelConfig, batch: int) -> dict:
    di = cfg.expand * cfg.d_model
    h = max(di // 64, 1)
    p = di // h
    return {
        "S": ParamSpec((batch, h, p, cfg.d_state), (None, None, None, None), "zeros", jnp.float32),
        "conv": ParamSpec((batch, cfg.d_conv - 1, di + 2 * cfg.d_state),
                          (None, None, None), "zeros", cfg.dtype),
    }


def mamba2_apply(p, x, cfg: ModelConfig, state=None):
    b, s, d = x.shape
    di = cfg.expand * d
    n = cfg.d_state
    h = max(di // 64, 1)
    ph = di // h
    xn = L.rmsnorm(p["ln"], x)
    proj = xn @ p["in_proj"]
    z, xin, dt_raw = proj[..., :di], proj[..., di : 2 * di], proj[..., 2 * di + 2 * n :]
    bc = proj[..., 2 * di : 2 * di + 2 * n]
    conv_in = jnp.concatenate([xin, bc], axis=-1)
    conv_state = None if state is None else state["conv"]
    conv_out, conv_new = _causal_depthwise_conv(conv_in, p["conv"], conv_state)
    conv_out = jax.nn.silu(conv_out)
    xc = conv_out[..., :di].reshape(b, s, h, ph)
    bmat = conv_out[..., di : di + n]
    cmat = conv_out[..., di + n :]

    a = -jnp.exp(p["a_log"])  # (H,)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)

    S0 = (
        jnp.zeros((b, h, ph, n), jnp.float32) if state is None else state["S"]
    )

    def step(S, xs):
        xt, bt, ct, dtt = xs  # (B,H,P), (B,N), (B,N), (B,H)
        decay = jnp.exp(a * dtt)[..., None, None]  # (B,H,1,1)
        S = decay * S + (dtt[..., None] * xt.astype(jnp.float32))[..., None] * bt[
            :, None, None, :
        ].astype(jnp.float32)
        y = jnp.einsum("bhpn,bn->bhp", S, ct.astype(jnp.float32))
        return S, y

    S, ys = lax.scan(
        step,
        S0,
        (
            xc.transpose(1, 0, 2, 3),
            bmat.transpose(1, 0, 2),
            cmat.transpose(1, 0, 2),
            dt.transpose(1, 0, 2),
        ),
    )
    y = ys.transpose(1, 0, 2, 3)  # (B,S,H,P)
    y = y + p["d_skip"][:, None] * xc.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(x.dtype)
    mu = y.reshape(b, s, h, ph).mean(-1, keepdims=True)
    var = jnp.var(y.reshape(b, s, h, ph), axis=-1, keepdims=True)
    y = ((y.reshape(b, s, h, ph) - mu) * lax.rsqrt(var + 1e-6)).reshape(b, s, di)
    y = (y * p["gn"] * jax.nn.silu(z)) @ p["out_proj"]
    return y, {"S": S, "conv": conv_new}
