"""Dense decoder-only transformer family (llama-arch): deepseek-7b, yi-6b,
tinyllama-1.1b, qwen1.5-110b (QKV bias), and the internvl2 LM backbone
(family="vlm": precomputed patch embeddings are prepended to the sequence).

Layers are scanned with stacked parameters so the lowered HLO is O(1 layer)
— essential for 80-layer models on 512-device dry-run compiles.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models.base import ParamSpec


def norm_specs(cfg: ModelConfig):
    return L.rmsnorm_specs(cfg.d_model) if cfg.norm == "rmsnorm" else L.layernorm_specs(cfg.d_model)


def norm(cfg: ModelConfig, p, x):
    return L.rmsnorm(p, x) if cfg.norm == "rmsnorm" else L.layernorm(p, x)


def attn_specs(cfg: ModelConfig) -> dict:
    d, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = {
        "wq": ParamSpec((d, h * dh), ("embed", "heads"), "scaled"),
        "wk": ParamSpec((d, hk * dh), ("embed", "kv_heads"), "scaled"),
        "wv": ParamSpec((d, hk * dh), ("embed", "kv_heads"), "scaled"),
        "wo": ParamSpec((h * dh, d), ("heads", "embed"), "scaled"),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((h * dh,), ("heads",), "zeros")
        s["bk"] = ParamSpec((hk * dh,), ("kv_heads",), "zeros")
        s["bv"] = ParamSpec((hk * dh,), ("kv_heads",), "zeros")
    return s


def qkv(p, x, cfg: ModelConfig, positions, rope: bool = True):
    b, s, _ = x.shape
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"] + p["bq"] if "bq" in p else x @ p["wq"]).reshape(b, s, h, dh)
    k = (x @ p["wk"] + p["bk"] if "bk" in p else x @ p["wk"]).reshape(b, s, hk, dh)
    v = (x @ p["wv"] + p["bv"] if "bv" in p else x @ p["wv"]).reshape(b, s, hk, dh)
    if rope:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_block(p, x, cfg: ModelConfig, positions, *, causal=True, window=0):
    b, s, _ = x.shape
    q, k, v = qkv(p, x, cfg, positions)
    o = attn.blockwise_attention(q, k, v, causal=causal, window=window)
    return o.reshape(b, s, -1) @ p["wo"]


def layer_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": norm_specs(cfg),
        "attn": attn_specs(cfg),
        "ln2": norm_specs(cfg),
        "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff, gated=cfg.act == "silu"),
    }


def stack_specs(n: int, tree):
    """Prepend a scanned 'layers' axis to every ParamSpec in the tree."""
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init, s.dtype),
        tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def specs(cfg: ModelConfig) -> dict:
    return {
        "embed": L.embedding_specs(cfg.vocab, cfg.d_model),
        "layers": stack_specs(cfg.n_layers, layer_specs(cfg)),
        "ln_f": norm_specs(cfg),
    }


def _embed_inputs(params, batch, cfg: ModelConfig):
    x = L.embed(params["embed"], batch["tokens"]).astype(cfg.dtype)
    if cfg.family == "vlm" and "img_embeds" in batch:
        x = jnp.concatenate([batch["img_embeds"].astype(cfg.dtype), x], axis=1)
    return x


def forward(params, batch, cfg: ModelConfig):
    """Full-sequence forward -> final hidden states (B, S, D)."""
    x = _embed_inputs(params, batch, cfg)
    positions = jnp.arange(x.shape[1])

    def layer(x, lp):
        h = x + attn_block(lp["attn"], norm(cfg, lp["ln1"], x), cfg, positions,
                           window=cfg.window)
        h = h + L.mlp(lp["mlp"], norm(cfg, lp["ln2"], h), cfg.act)
        return h, None

    body = jax.checkpoint(layer) if cfg.remat else layer
    x, _ = lax.scan(body, x, params["layers"])
    return norm(cfg, params["ln_f"], x)


def loss_fn(params, batch, cfg: ModelConfig):
    x = forward(params, batch, cfg)
    labels = batch["labels"]
    if cfg.family == "vlm" and "img_embeds" in batch:
        n_img = batch["img_embeds"].shape[1]
        x = x[:, n_img:]
    if cfg.xent_chunk:
        return L.tied_xent_chunked(params["embed"], x, labels, cfg.vocab, cfg.xent_chunk)
    logits = L.lm_logits(params["embed"], x, cfg.vocab)
    return L.softmax_xent(logits, labels)


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode over a KV cache
# ---------------------------------------------------------------------------
def cache_len(cfg: ModelConfig, seq_len: int) -> int:
    return min(seq_len, cfg.window) if cfg.window else seq_len


# --- RARO dense-tier quantized KV (§Perf iteration: kv_bits = 8 / 4) ------
def _kv_qmax(bits: int) -> float:
    return 127.0 if bits == 8 else 7.0


def quant_kv(x, bits: int):
    """x: (..., dh) -> (q int8 (packed for 4-bit), scale (...,) f32)."""
    x32 = x.astype(jnp.float32)
    qmax = _kv_qmax(bits)
    scale = jnp.maximum(jnp.max(jnp.abs(x32), axis=-1), 1e-8) / qmax
    q = jnp.clip(jnp.round(x32 / scale[..., None]), -qmax, qmax).astype(jnp.int8)
    if bits == 4:
        q = (q[..., 0::2] & 0x0F) | ((q[..., 1::2] & 0x0F) << 4)
    return q.astype(jnp.int8), scale


def dequant_kv(q, scale, bits: int, dtype):
    if bits == 4:
        lo = ((q & 0x0F) ^ 0x08) - 0x08
        hi = q >> 4
        q = jnp.stack([lo, hi], axis=-1).reshape(*q.shape[:-1], 2 * q.shape[-1])
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def init_cache_specs(cfg: ModelConfig, batch: int, seq_len: int):
    s = cache_len(cfg, seq_len)
    hk, dh = cfg.n_kv_heads, cfg.head_dim
    if cfg.kv_bits == 16:
        kv = ParamSpec((cfg.n_layers, batch, s, hk, dh),
                       ("layers", None, None, "kv_heads", None), "zeros", cfg.dtype)
        return {"k": kv, "v": kv}
    dhq = dh if cfg.kv_bits == 8 else dh // 2
    kv = ParamSpec((cfg.n_layers, batch, s, hk, dhq),
                   ("layers", None, None, "kv_heads", None), "zeros", jnp.int8)
    sc = ParamSpec((cfg.n_layers, batch, s, hk),
                   ("layers", None, None, "kv_heads"), "ones", jnp.float32)
    return {"k": kv, "v": kv, "k_scale": sc, "v_scale": sc}


def prefill(params, batch, cfg: ModelConfig):
    """Full-sequence pass that also materializes the KV cache.

    Returns (last-position logits, cache dict).
    """
    x = _embed_inputs(params, batch, cfg)
    positions = jnp.arange(x.shape[1])

    def layer(x, lp):
        xn = norm(cfg, lp["ln1"], x)
        q, k, v = qkv(lp["attn"], xn, cfg, positions)
        o = attn.blockwise_attention(q, k, v, causal=True, window=cfg.window)
        h = x + o.reshape(x.shape[0], x.shape[1], -1) @ lp["attn"]["wo"]
        h = h + L.mlp(lp["mlp"], norm(cfg, lp["ln2"], h), cfg.act)
        return h, (k, v)

    x, (ks, vs) = lax.scan(layer, x, params["layers"])
    x = norm(cfg, params["ln_f"], x)
    logits = L.lm_logits(params["embed"], x[:, -1:], cfg.vocab)
    w = cache_len(cfg, x.shape[1])
    ks, vs = ks[:, :, -w:], vs[:, :, -w:]
    if cfg.kv_bits == 16:
        return logits, {"k": ks, "v": vs}
    qk, sk = quant_kv(ks, cfg.kv_bits)
    qv, sv = quant_kv(vs, cfg.kv_bits)
    return logits, {"k": qk, "v": qv, "k_scale": sk, "v_scale": sv}


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    """One decode step. tokens: (B, 1); pos: (B,) absolute positions.

    The cache write index is ``pos % cache_size`` (rolling buffer, which for
    window archs implements the sliding window exactly). With kv_bits < 16
    the cache holds int8/packed-int4 pages + per-token scales (the RARO
    dense tier); reads dequantize on the fly.
    """
    b = tokens.shape[0]
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    s_cache = cache["k"].shape[2]
    widx = pos % s_cache
    bidx = jnp.arange(b)
    quant = cfg.kv_bits < 16

    def layer(x, xs):
        if quant:
            lp, kc, vc, ksc, vsc = xs
        else:
            lp, kc, vc = xs
        xn = norm(cfg, lp["ln1"], x)
        q, k, v = qkv(lp["attn"], xn, cfg, pos[:, None])
        if quant:
            qk, sk = quant_kv(k[:, 0], cfg.kv_bits)
            qv, sv = quant_kv(v[:, 0], cfg.kv_bits)
            kc = kc.at[bidx, widx].set(qk)
            vc = vc.at[bidx, widx].set(qv)
            ksc = ksc.at[bidx, widx].set(sk)
            vsc = vsc.at[bidx, widx].set(sv)
            k_full = dequant_kv(kc, ksc, cfg.kv_bits, cfg.dtype)
            v_full = dequant_kv(vc, vsc, cfg.kv_bits, cfg.dtype)
        else:
            kc = kc.at[bidx, widx].set(k[:, 0])
            vc = vc.at[bidx, widx].set(v[:, 0])
            k_full, v_full = kc, vc
        o = attn.decode_attention(q, k_full, v_full, jnp.minimum(pos + 1, s_cache))
        h = x + o.reshape(b, 1, -1) @ lp["attn"]["wo"]
        h = h + L.mlp(lp["mlp"], norm(cfg, lp["ln2"], h), cfg.act)
        return h, (kc, vc, ksc, vsc) if quant else (kc, vc)

    if quant:
        x, (ks, vs, kss, vss) = lax.scan(
            layer, x,
            (params["layers"], cache["k"], cache["v"], cache["k_scale"], cache["v_scale"]),
        )
        new_cache = {"k": ks, "v": vs, "k_scale": kss, "v_scale": vss}
    else:
        x, (ks, vs) = lax.scan(layer, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs}
    x = norm(cfg, params["ln_f"], x)
    logits = L.lm_logits(params["embed"], x, cfg.vocab)
    return logits, new_cache
