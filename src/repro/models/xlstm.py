"""xLSTM language model (sLSTM + mLSTM blocks, arXiv:2405.04517).

Every ``slstm_every``-th block is sLSTM, the rest mLSTM. Attention-free:
decode state is O(1) per layer; there is no KV cache and the RARO tiering
technique is inapplicable (DESIGN.md §5 Arch-applicability).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm
from repro.models import transformer as T


def _is_slstm(cfg: ModelConfig, idx):
    if not cfg.slstm_every:
        return jnp.zeros_like(idx, bool) if hasattr(idx, "shape") else False
    return (idx % cfg.slstm_every) == (cfg.slstm_every - 1)


def specs(cfg: ModelConfig) -> dict:
    layer = {"mlstm": ssm.mlstm_specs(cfg), "slstm": ssm.slstm_specs(cfg)}
    return {
        "embed": L.embedding_specs(cfg.vocab, cfg.d_model),
        "layers": T.stack_specs(cfg.n_layers, layer),
        "ln_f": T.norm_specs(cfg),
    }


def init_cache_specs(cfg: ModelConfig, batch: int, seq_len: int):
    m = T.stack_specs(cfg.n_layers, ssm.mlstm_state_specs(cfg, batch))
    s = T.stack_specs(cfg.n_layers, ssm.slstm_state_specs(cfg, batch))
    return {"mlstm": m, "slstm": s}


def _layer(cfg, lp, x, mstate, sstate):
    """One block with optional carried state; returns (y, mstate', sstate')."""

    def do_m(ops):
        x, ms, ss = ops
        y, ms2 = ssm.mlstm_apply(lp["mlstm"], x, cfg, ms)
        return y, ms2, ss

    def do_s(ops):
        x, ms, ss = ops
        y, ss2 = ssm.slstm_apply(lp["slstm"], x, cfg, ss)
        return y, ms, ss2

    return do_m, do_s


def _scan_layers(params, x, cfg: ModelConfig, cache=None):
    n = cfg.n_layers
    idxs = jnp.arange(n)
    if cache is None:
        b = x.shape[0]
        cache = {
            "mlstm": jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype),
                T.stack_specs(n, ssm.mlstm_state_specs(cfg, b)),
                is_leaf=lambda z: hasattr(z, "init"),
            ),
            "slstm": jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype),
                T.stack_specs(n, ssm.slstm_state_specs(cfg, b)),
                is_leaf=lambda z: hasattr(z, "init"),
            ),
        }

    def body(x, xs):
        lp, ms, ss, idx = xs
        do_m, do_s = _layer(cfg, lp, x, ms, ss)
        y, ms2, ss2 = lax.cond(_is_slstm(cfg, idx), do_s, do_m, (x, ms, ss))
        return x + y, (ms2, ss2)

    x, (ms_all, ss_all) = lax.scan(
        body, x, (params["layers"], cache["mlstm"], cache["slstm"], idxs)
    )
    return x, {"mlstm": ms_all, "slstm": ss_all}


def forward(params, batch, cfg: ModelConfig):
    x = L.embed(params["embed"], batch["tokens"]).astype(cfg.dtype)
    x, _ = _scan_layers(params, x, cfg)
    return T.norm(cfg, params["ln_f"], x)


def loss_fn(params, batch, cfg: ModelConfig):
    x = forward(params, batch, cfg)
    logits = L.lm_logits(params["embed"], x, cfg.vocab)
    return L.softmax_xent(logits, batch["labels"])


def prefill(params, batch, cfg: ModelConfig):
    x = L.embed(params["embed"], batch["tokens"]).astype(cfg.dtype)
    x, cache = _scan_layers(params, x, cfg)
    x = T.norm(cfg, params["ln_f"], x)
    logits = L.lm_logits(params["embed"], x[:, -1:], cfg.vocab)
    return logits, cache


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    del pos  # recurrent state carries position implicitly
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    x, cache = _scan_layers(params, x, cfg, cache)
    x = T.norm(cfg, params["ln_f"], x)
    return L.lm_logits(params["embed"], x, cfg.vocab), cache
