"""Gradient compression with error feedback for the cross-pod (DCN) axis.

int8 symmetric quantization per tensor; the quantization residual is kept
locally and added to the next step's gradient (error feedback), so the
compressed SGD trajectory tracks the exact one (Karimireddy et al., 2019).
``compressed_allreduce`` is the shard_map building block: all_gather the
int8 payload + scales (8x less DCN traffic than f32), dequantize-and-sum
locally.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress(x, err):
    """-> (q int8, scale f32 scalar, new_err). err may be None."""
    x32 = x.astype(jnp.float32)
    if err is not None:
        x32 = x32 + err
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    new_err = x32 - q.astype(jnp.float32) * scale
    return q, scale, new_err


def decompress(q, scale):
    return q.astype(jnp.float32) * scale


def compress_tree(grads, err_tree):
    """Tree-mapped compress. err_tree may be None on the first step."""
    leaves, td = jax.tree_util.tree_flatten(grads)
    errs = jax.tree_util.tree_leaves(err_tree) if err_tree is not None else [None] * len(leaves)
    qs, scales, new_errs = [], [], []
    for g, e in zip(leaves, errs):
        q, s, ne = compress(g, e)
        qs.append(q)
        scales.append(s)
        new_errs.append(ne)
    u = jax.tree_util.tree_unflatten
    return u(td, qs), u(td, scales), u(td, new_errs)


def decompress_tree(qs, scales):
    return jax.tree_util.tree_map(decompress, qs, scales)


def compressed_allreduce(x, err, axis_name: str):
    """Mean-allreduce of x over ``axis_name`` sending int8 + scale instead
    of f32 (use inside shard_map). Returns (mean, new_err)."""
    q, scale, new_err = compress(x, err)
    qg = jax.lax.all_gather(q, axis_name)  # int8 payload on the wire
    sg = jax.lax.all_gather(scale, axis_name)
    n = qg.shape[0]
    total = jnp.tensordot(sg, qg.astype(jnp.float32), axes=((0,), (0,)))
    return total / n, new_err
