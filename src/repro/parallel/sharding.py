"""Sharding rules: logical axes -> mesh axes, per architecture.

2D layout: ("data", "model") within a pod, plus an optional leading "pod"
axis that composes with "data" for batch/gradient parallelism (the lowest-
bandwidth axis carries the lowest-frequency collective — one gradient
reduction per step).

Every rule is divisibility-checked against the actual mesh (base.
spec_partition falls back to replication per-dim), so one rule set serves
every (arch x shape x mesh) cell; per-arch overrides below pick the better
axis when the default is unshardable (e.g. granite's 40 experts on a
16-way model axis -> shard the expert FFN width instead).
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import base


def data_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def tp_size(mesh) -> int:
    return mesh.shape["model"]


def make_rules(cfg: ModelConfig, mesh) -> dict:
    tp = tp_size(mesh)
    rules = dict(base.DEFAULT_RULES)
    # GQA: shard KV projections over heads only when heads divide cleanly;
    # otherwise replicate KV (queries stay head-sharded).
    if cfg.n_kv_heads % tp != 0:
        rules["kv_heads"] = None
    if cfg.n_heads % tp != 0:
        rules["heads"] = None
    # MoE: expert-parallel when E % tp == 0, else tensor-parallel experts.
    rules["moe_ff"] = None
    if cfg.n_experts:
        if cfg.n_experts % tp == 0:
            rules["experts"] = "model"
        else:
            rules["experts"] = None
            rules["moe_ff"] = "model"
    # batch-like axes (inputs, caches)
    rules["batch"] = data_axes(mesh)
    rules["seq"] = None
    return rules


def param_shardings(cfg: ModelConfig, specs, mesh):
    return base.param_shardings(specs, mesh, make_rules(cfg, mesh))


def _spec_for(shape, axes, rules, mesh) -> P:
    out = []
    used = set()
    for dim, ax in zip(shape, axes):
        mesh_ax = rules.get(ax)
        if mesh_ax is None:
            out.append(None)
            continue
        if isinstance(mesh_ax, tuple):
            size = 1
            for a in mesh_ax:
                size *= mesh.shape[a]
        else:
            size = mesh.shape[mesh_ax]
        key = mesh_ax if isinstance(mesh_ax, str) else mesh_ax[0]
        if dim % size == 0 and key not in used:
            out.append(mesh_ax)
            used.add(key)
        else:
            out.append(None)
    return P(*out)


# logical axes of the standard batch inputs
_BATCH_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "frames": ("batch", "seq", None),
    "img_embeds": ("batch", "seq", None),
    "pos": ("batch",),
}


def batch_shardings(cfg: ModelConfig, batch_abstract, mesh):
    """NamedShardings for a train/prefill batch dict or the decode inputs
    (tokens/pos/cache)."""
    rules = make_rules(cfg, mesh)

    def shard_one(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        axes = _BATCH_AXES.get(name)
        if axes is None:
            axes = (None,) * len(x.shape)
        return NamedSharding(mesh, _spec_for(x.shape, axes[: len(x.shape)], rules, mesh))

    def walk(tree, in_cache=False):
        out = {}
        for k, v in tree.items():
            if k == "cache":
                out[k] = cache_shardings(cfg, v, mesh)
            elif isinstance(v, dict):
                out[k] = walk(v)
            else:
                axes = _BATCH_AXES.get(k, (None,) * len(v.shape))
                out[k] = NamedSharding(mesh, _spec_for(v.shape, axes[: len(v.shape)], rules, mesh))
        return out

    return walk(batch_abstract)


def cache_shardings(cfg: ModelConfig, cache_abstract, mesh, *, seq_shard: bool = False):
    """KV/recurrent-state cache shardings: batch over data axes, kv heads
    over model where divisible (falls back per-dim automatically).

    seq_shard=True (§Perf iteration 1): when the KV-head dim cannot use the
    model axis (GQA kv_heads < tp, or MLA's un-headed latent), shard the
    cache SEQUENCE dim over "model" instead — flash-decoding-style split-K;
    XLA turns the softmax reductions into small (B, H) collectives instead
    of all-gathering the whole cache.
    """
    rules = make_rules(cfg, mesh)
    tp = tp_size(mesh)

    # We re-derive axes from shapes: dim 0 = layers/apps, dim 1 = batch, the
    # dim matching n_kv_heads = kv_heads; for 4D (L,B,S,R) latent caches dim
    # 2 is the sequence.
    def one(x):
        axes: list = []
        for i, dim in enumerate(x.shape):
            if i == 0 and len(x.shape) >= 3:
                axes.append(None)  # layers / apps
            elif (i == 1 and len(x.shape) >= 3) or (i == 0 and len(x.shape) < 3):
                axes.append("batch")
            elif dim == cfg.n_kv_heads and i >= 2:
                axes.append("kv_heads")
            elif cfg.family in ("ssm", "hybrid") and dim == cfg.n_heads and i >= 2:
                axes.append("heads")
            else:
                axes.append(None)
        spec = _spec_for(x.shape, tuple(axes), rules, mesh)
        if seq_shard and "model" not in jax.tree_util.tree_leaves(spec) and len(x.shape) >= 4:
            # no model-axis use -> shard the seq dim (index 2) if divisible
            if x.shape[2] % tp == 0:
                parts = list(spec) + [None] * (len(x.shape) - len(spec))
                parts[2] = "model"
                spec = P(*parts)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(one, cache_abstract)
