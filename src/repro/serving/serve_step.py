"""Serving steps: prefill + greedy/temperature decode over the model's KV
cache. The decode_32k / long_500k dry-run cells lower ``serve_step`` (one
new token against a seq_len-deep cache), per the assignment."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import registry


def make_prefill(cfg: ModelConfig):
    api = registry.get_api(cfg)

    def prefill(params, batch):
        logits, cache = api.prefill(params, batch)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return prefill


def make_serve_step(cfg: ModelConfig, temperature: float = 0.0):
    api = registry.get_api(cfg)

    def serve_step(params, cache, tokens, pos, rng=None):
        logits, cache = api.decode_step(params, cache, tokens, pos)
        logits = logits[:, -1].astype(jnp.float32)
        if temperature > 0.0 and rng is not None:
            next_tok = jax.random.categorical(rng, logits / temperature)
        else:
            next_tok = jnp.argmax(logits, axis=-1)
        return next_tok.astype(jnp.int32), cache

    return serve_step
