# FEMU-analogue vectorized flash-storage simulator (DESIGN.md §2A).
from repro.ssdsim import (  # noqa: F401
    engine,
    ftl,
    geometry,
    obs,
    policies,
    state,
    telemetry,
    trace_export,
    workload,
)

__all__ = [
    "engine",
    "ftl",
    "geometry",
    "obs",
    "policies",
    "state",
    "telemetry",
    "trace_export",
    "workload",
]
