# FEMU-analogue vectorized flash-storage simulator (DESIGN.md §2A).
from repro.ssdsim import engine, ftl, geometry, policies, state, workload  # noqa: F401

__all__ = ["engine", "ftl", "geometry", "policies", "state", "workload"]
