"""Chunked vectorized simulation engine (DESIGN.md §2A).

One engine step processes ``cfg.chunk`` requests: reads are fully
vectorized (metadata gathers + segment-sum accounting), then the policy's
per-read trigger pipeline runs on the chunk's unique read set, conversions/
reclaim/GC execute as background FTL tasks, exactly like FEMU's background
loop between request bursts.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import hotness, modes, reclaim, retry
from repro.ssdsim import ftl, geometry, policies, telemetry
from repro.ssdsim import state as st

OP_READ = 0
OP_WRITE = 1


class ChunkMetrics(NamedTuple):
    capacity_pages: jnp.ndarray
    free_blocks: jnp.ndarray
    mode_hist: jnp.ndarray  # (3,) blocks per mode (non-free)
    reads: jnp.ndarray
    retries: jnp.ndarray
    svc_ms: jnp.ndarray  # total read service time this chunk
    migrated: jnp.ndarray
    lat_hist: jnp.ndarray  # (telemetry.N_LAT_BINS,) this chunk's read latencies


def lookup(s: st.SSDState, lpns, cfg: geometry.SimConfig):
    """Gather physical metadata + Eq.-3 retry estimate for logical pages."""
    lp = jnp.maximum(lpns, 0)
    slot = s.l2p[lp]
    ok = (lpns >= 0) & (slot >= 0)
    slot = jnp.where(ok, slot, 0)
    blk = slot // cfg.slots_per_block
    mode = s.block_mode[blk]
    age_h = cfg.device_age_h + (s.clock_ms - s.page_write_ms[slot]) / 3.6e6
    retries = retry.page_retries(mode, s.block_pe[blk], age_h, s.block_reads[blk], slot)
    return slot, blk, mode, retries, ok


def _write_path(s: st.SSDState, lpns, is_write, cfg: geometry.SimConfig):
    """Sequential user-write path (inner scan; only traced for mixed
    workloads). Writes append to the per-LUN open QLC block."""
    spb = cfg.slots_per_block
    ppb = geometry.pages_per_block(cfg)
    ppb_q = ppb[modes.QLC]

    def wstep(s, x):
        lpn, active = x

        def do(s):
            lun = (lpn % cfg.n_luns).astype(jnp.int32)
            d = s.open_user[lun]
            dd0 = jnp.maximum(d, 0)
            need_new = (d < 0) | (s.block_next[dd0] >= ppb_q)
            a = ftl.alloc_free_block(s, prefer_lun=lun, cfg=cfg)
            d2 = jnp.where(need_new, a, d)
            ok = d2 >= 0
            dd = jnp.maximum(d2, 0)
            # open fresh block in QLC mode
            s = s._replace(
                block_mode=s.block_mode.at[dd].set(
                    jnp.where(ok & need_new, modes.QLC, s.block_mode[dd])
                ),
                block_state=s.block_state.at[dd].set(
                    jnp.where(ok & need_new, st.OPEN, s.block_state[dd])
                ),
            )
            # invalidate previous mapping
            old = s.l2p[lpn]
            has_old = ok & (old >= 0)
            old_blk = jnp.maximum(old, 0) // spb
            s = s._replace(
                p2l=s.p2l.at[jnp.where(has_old, old, cfg.n_slots)].set(-1, mode="drop"),
                block_valid=s.block_valid.at[jnp.where(has_old, old_blk, s.block_valid.shape[0])].add(
                    -1, mode="drop"
                ),
            )
            slot = dd * spb + s.block_next[dd]
            nxt = s.block_next[dd] + 1
            full = nxt >= ppb_q
            s = s._replace(
                l2p=s.l2p.at[jnp.where(ok, lpn, cfg.n_logical)].set(slot, mode="drop"),
                p2l=s.p2l.at[jnp.where(ok, slot, cfg.n_slots)].set(lpn, mode="drop"),
                page_write_ms=s.page_write_ms.at[jnp.where(ok, slot, cfg.n_slots)].set(
                    s.clock_ms, mode="drop"
                ),
                block_next=s.block_next.at[dd].add(jnp.where(ok, 1, 0)),
                block_valid=s.block_valid.at[dd].add(jnp.where(ok, 1, 0)),
                block_state=s.block_state.at[dd].set(
                    jnp.where(ok & full, st.FULL, s.block_state.at[dd].get())
                ),
                open_user=s.open_user.at[lun].set(jnp.where(ok & ~full, d2, -1)),
                lun_busy_ms=s.lun_busy_ms.at[lun].add(
                    jnp.where(ok, modes.WRITE_LATENCY_US[modes.QLC] / 1000.0, 0.0)
                ),
                n_writes=s.n_writes + jnp.where(ok, 1.0, 0.0),
            )
            return s

        return lax.cond(active, do, lambda s_: s_, s), None

    s, _ = lax.scan(wstep, s, (jnp.maximum(lpns, 0), is_write & (lpns >= 0)))
    return s


def step_chunk(s: st.SSDState, req, cfg: geometry.SimConfig, has_writes: bool,
               knobs: policies.RunKnobs | None = None):
    """One engine step. ``knobs`` optionally supplies traced overrides for
    the batchable policy/wear knobs (sweep runner); ``None`` reads them from
    ``cfg`` as before."""
    lpns, ops = req
    is_read = ops == OP_READ

    # ---------------- reads (vectorized) ----------------
    slot, blk, mode, retries, ok = lookup(s, lpns, cfg)
    rd = is_read & ok
    svc_us = jnp.where(rd, retry.read_latency_us(mode, retries), 0.0)
    xfer_us = jnp.where(rd, cfg.transfer_us, 0.0)
    lun = blk % cfg.n_luns
    chan = lun % cfg.n_channels

    lun_add = jax.ops.segment_sum(svc_us, lun, num_segments=cfg.n_luns) / 1000.0
    chan_add = jax.ops.segment_sum(xfer_us, chan, num_segments=cfg.n_channels) / 1000.0
    chunk_reads = rd.sum().astype(jnp.float32)
    chunk_retries = jnp.where(rd, retries, 0).sum().astype(jnp.float32)
    chunk_svc = (svc_us + xfer_us).sum() / 1000.0
    chunk_hist = telemetry.record(
        jnp.zeros((telemetry.N_LAT_BINS,), jnp.float32), svc_us + xfer_us, rd
    )

    s = s._replace(
        lun_busy_ms=s.lun_busy_ms + lun_add,
        chan_busy_ms=s.chan_busy_ms + chan_add,
        block_reads=s.block_reads
        + jax.ops.segment_sum(rd.astype(jnp.int32), blk, num_segments=cfg.n_blocks),
        svc_sum_ms=s.svc_sum_ms + chunk_svc,
        n_reads=s.n_reads + chunk_reads,
        n_retries=s.n_retries + chunk_retries,
        lat_hist=s.lat_hist + chunk_hist,
    )

    # ---------------- heat update ----------------
    touched = rd | (ops == OP_WRITE)
    heat = hotness.decay_heat(s.heat, cfg.heat)
    heat = heat.at[jnp.where(touched, lpns, cfg.n_logical)].add(1.0, mode="drop")
    s = s._replace(heat=heat)

    # ---------------- user writes ----------------
    if has_writes:
        s = _write_path(s, lpns, ops == OP_WRITE, cfg)

    # ---------------- policy: conversion migrations ----------------
    if cfg.policy != geometry.BASELINE:
        uniq = jnp.unique(jnp.where(rd, lpns, -1), size=cfg.chunk, fill_value=-1)
        slot_u, blk_u, mode_u, retr_u, ok_u = lookup(s, uniq, cfg)
        heat_u = s.heat[jnp.maximum(uniq, 0)]
        sel = policies.select_migrations(
            cfg, uniq, mode_u, retr_u, heat_u, ok_u, s.block_pe[blk_u], knobs=knobs
        )
        for tgt in (modes.SLC, modes.TLC):
            s = ftl.maybe_migrate_pages(s, sel[tgt], tgt, cfg)

        # ---------------- elastic capacity recovery ----------------
        if cfg.reclaim_enabled:
            cls_rd = hotness.classify(s.heat[jnp.maximum(lpns, 0)], cfg.heat)
            hw = rd & (cls_rd >= modes.WARM)
            touched_blk = (
                jax.ops.segment_max(
                    hw.astype(jnp.int32), blk, num_segments=cfg.n_blocks
                )
                > 0
            )
            s = s._replace(
                block_cold_age=jnp.where(touched_blk, 0, s.block_cold_age + 1)
            )
            free_frac = ftl.free_block_count(s) / cfg.n_blocks
            rcfg = reclaim.ReclaimConfig(max_per_pass=cfg.max_conversions_per_chunk)
            eligible_mode = jnp.where(
                s.block_state == st.FULL, s.block_mode, modes.QLC
            )  # only FULL low-density blocks are demotable
            # Per-block residual heat = max heat over the block's valid pages
            # (the demotion tie-breaker: among equally long-cold blocks, the
            # one with the least residual heat demotes first).
            slot_blk = jnp.arange(cfg.n_slots, dtype=jnp.int32) // cfg.slots_per_block
            page_heat = jnp.where(s.p2l >= 0, s.heat[jnp.maximum(s.p2l, 0)], 0.0)
            block_heat = jnp.maximum(
                jax.ops.segment_max(page_heat, slot_blk, num_segments=cfg.n_blocks),
                0.0,
            )
            mask, tgt_modes = reclaim.select_demotions(
                eligible_mode, block_heat,
                s.block_cold_age, free_frac, rcfg,
            )
            score = jnp.where(mask, s.block_cold_age, -1)
            for _ in range(cfg.max_conversions_per_chunk):
                b = jnp.argmax(score).astype(jnp.int32)
                src = jnp.where(score[b] > 0, b, -1)
                s = ftl.maybe_migrate_block(s, src, tgt_modes[jnp.maximum(b, 0)], cfg)
                score = score.at[b].set(-1)

    # ---------------- GC ----------------
    s = ftl.gc_step(s, cfg)

    # clock follows the busiest LUN (device saturated under FIO load)
    s = s._replace(clock_ms=jnp.maximum(s.clock_ms, s.lun_busy_ms.max()))

    nonfree = s.block_state != st.FREE
    mode_hist = jax.ops.segment_sum(
        nonfree.astype(jnp.int32), s.block_mode, num_segments=3
    )
    y = ChunkMetrics(
        capacity_pages=st.usable_capacity_pages(s, cfg),
        free_blocks=ftl.free_block_count(s),
        mode_hist=mode_hist,
        reads=chunk_reads,
        retries=chunk_retries,
        svc_ms=chunk_svc,
        migrated=s.n_migrated_pages,
        lat_hist=chunk_hist,
    )
    return s, y


@partial(jax.jit, static_argnums=(0, 3))
def _run_jit(cfg: geometry.SimConfig, lpns, ops, has_writes: bool):
    s0 = st.init_state(cfg)

    def body(s, x):
        return step_chunk(s, x, cfg, has_writes)

    return lax.scan(body, s0, (lpns, ops))


def run(cfg: geometry.SimConfig, trace, has_writes: bool | None = None):
    """Run a full trace. ``trace`` is a dict with 'lpn' and 'op' arrays of
    shape (n_chunks, cfg.chunk). Returns (final_state, ChunkMetrics stacked).
    """
    if has_writes is None:
        has_writes = bool((trace["op"] == OP_WRITE).any())
    lpns = jnp.asarray(trace["lpn"], jnp.int32)
    ops = jnp.asarray(trace["op"], jnp.int32)
    return _run_jit(cfg, lpns, ops, has_writes)


def summarize(s: st.SSDState, cfg: geometry.SimConfig, threads: int = 4):
    """Headline numbers for the paper's figures."""
    import numpy as np

    n_reads = float(s.n_reads)
    makespan_ms = float(jnp.maximum(s.lun_busy_ms.max(), s.chan_busy_ms.max()))
    mean_lat_ms = float(s.svc_sum_ms) / max(n_reads, 1.0)
    if threads == 1:
        # synchronous single-thread: no inter-LUN overlap; background work
        # (migrations/GC) still steals device time via the makespan term.
        iops = 1000.0 / mean_lat_ms if mean_lat_ms > 0 else 0.0
    else:
        iops = n_reads / max(makespan_ms / 1000.0, 1e-9)
    cap = float(st.capacity_gib(s, cfg))
    init_cap = cfg.n_blocks * cfg.slots_per_block * cfg.page_bytes / 2**30
    pct = telemetry.percentiles(s.lat_hist)
    return dict(
        iops=iops,
        mean_read_latency_us=mean_lat_ms * 1000.0,
        read_lat_p50_us=pct[0.5],
        read_lat_p95_us=pct[0.95],
        read_lat_p99_us=pct[0.99],
        read_lat_p999_us=pct[0.999],
        retries_per_read=float(s.n_retries) / max(n_reads, 1.0),
        capacity_gib=cap,
        capacity_loss_gib=init_cap - cap,
        migrated_pages=float(s.n_migrated_pages),
        erases=float(s.n_erases),
        conversions=np.asarray(s.n_conversions),
        reads=n_reads,
        writes=float(s.n_writes),
    )
