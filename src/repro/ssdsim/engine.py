"""Chunked vectorized simulation engine (DESIGN.md §2A).

One engine step processes ``cfg.chunk`` requests: reads are fully
vectorized (metadata gathers + segment-sum accounting), user writes run
through the batched write path (per-LUN prefix sums + masked scatters; the
sequential scan survives only as the test reference), then the policy's
per-read trigger pipeline runs on the chunk's unique read set and
conversions/reclaim/GC execute as pressure-gated background FTL tasks,
exactly like FEMU's background loop between request bursts. All block
relocation — multi-victim GC (up to ``cfg.gc_victims_per_pass`` per
firing), reclaim demotion and conversion — runs through the one fused
``ftl.relocate_group`` kernel (DESIGN.md §2A).

Two timing models share the engine (DESIGN.md §2C):

  closed loop (trace without ``arrival_ms``) — requests are serviced
  back-to-back; recorded read latency = sense/retry + transfer and the sim
  clock follows cumulative LUN busy time. The original behavior, bit-for-bit.

  open loop (trace with ``arrival_ms``) — each request has an arrival
  timestamp; requests queue FCFS per die behind earlier requests and behind
  background FTL work (migrations/reclaim/GC/erase), and the recorded
  latency adds the queueing delay, with departures from a vectorized
  per-lane Lindley recursion (:func:`_queue_departures`) against the
  ``die_avail_ms`` clocks. Under ``cfg.chan_model == "legacy"`` transfer is
  appended to the recorded latency but never queues (the historical
  one-clock-per-LUN model); under ``"lattice"`` the same recursion runs
  twice as a two-resource tandem (:func:`_tandem_departures`) — die pass
  for sense/program occupancy, then a channel pass where every page
  transfer serializes on its die's channel bus against ``chan_avail_ms``,
  so a read departs at max(die_free, chan_free_after_prior_transfers) +
  sense + retries + xfer.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import faults as flt
from repro.core import hotness, modes, reclaim, retry
from repro.ssdsim import ftl, geometry, obs, policies, telemetry
from repro.ssdsim import state as st

OP_READ = 0
OP_WRITE = 1


class ChunkMetrics(NamedTuple):
    capacity_pages: jnp.ndarray
    free_blocks: jnp.ndarray
    mode_hist: jnp.ndarray  # (3,) blocks per mode (non-free)
    reads: jnp.ndarray
    retries: jnp.ndarray
    svc_ms: jnp.ndarray  # total recorded read latency this chunk
    migrated: jnp.ndarray
    lat_hist: jnp.ndarray  # (telemetry.N_LAT_BINS,) this chunk's read latencies
    w_lat_hist: jnp.ndarray  # (telemetry.N_LAT_BINS,) this chunk's write latencies
    q_ms: jnp.ndarray  # total read queueing delay this chunk (0 closed-loop)
    chanq_ms: jnp.ndarray  # total read channel-wait this chunk (lattice only)
    user_pages: jnp.ndarray  # host pages written this chunk (WAF numerator lhs)
    reloc_pages: jnp.ndarray  # GC/conversion/reclaim pages moved this chunk


def _queue_departures(avail0_ms, arrival_ms, occ_ms, lun, active, n_luns: int):
    """Per-resource FCFS departure times for one chunk (vectorized Lindley).

    ``lun`` assigns each lane to a resource column (a die's command queue,
    or a channel bus in the lattice model's transfer pass). The classic
    recursion per resource, in request order,

        start_k = max(A_k, D_{k-1});  D_k = start_k + S_k

    closed-forms — with P_k the per-resource inclusive prefix sum of
    service times S and A_j the arrival times — to

        D_k = P_k + max(avail0_lun, max_{j<=k}(A_j - P_{j-1}))

    so one masked ``cumsum`` and one masked ``cummax`` per resource column
    replace a per-request scan. Arrivals need not be sorted: out-of-order
    A_j simply serve in lane (request-admission) order, which is what the
    tandem channel pass relies on. Inactive lanes neither occupy the
    resource nor constrain the max; a resource with no requests this chunk
    keeps ``avail0_lun``. Returns (per-lane departure times, final
    per-resource availability), both in ms.
    """
    oh = (lun[:, None] == jnp.arange(n_luns, dtype=jnp.int32)[None, :]) & active[:, None]
    sv = jnp.where(oh, occ_ms[:, None], 0.0)
    prefix = jnp.cumsum(sv, axis=0)  # (C, n_luns) inclusive per-lane P_k
    slack = jnp.where(oh, arrival_ms[:, None] - (prefix - sv), -jnp.inf)
    m = jnp.maximum(lax.cummax(slack, axis=0), avail0_ms[None, :])
    depart = prefix + m
    lane_dep = jnp.take_along_axis(
        depart, jnp.clip(lun, 0, n_luns - 1)[:, None], axis=1
    )[:, 0]
    return lane_dep, depart[-1]


def _tandem_departures(die_avail0, chan_avail0, arrival_ms, die_occ_ms,
                       xfer_ms, die, chan, rd, active, n_dies: int,
                       n_channels: int):
    """Two-resource tandem Lindley recursion (``chan_model="lattice"``).

    Stage 1 — the die: every active request queues FCFS on its die for its
    command occupancy (sense+retries for reads, page program for writes),
    exactly the legacy recursion. Stage 2 — the channel bus: every request's
    page transfer then queues FCFS on the die's channel for ``xfer_ms``. A
    read's transfer becomes eligible when its sense finishes (the die-pass
    departure: data sits in the page register, freeing the die — the
    decoupling that keeps both passes closed-form); a write's transfer is
    eligible at the request's arrival (the controller stages data to the
    die over the bus before/while the die drains earlier work, so write
    transfers contend for the bus without coupling the passes). The channel
    serves transfers in request-admission order (FCFS per bus).

    Returns ``(die_dep, chan_dep, die_avail, chan_avail)``: per-lane die
    and channel departure times plus the final per-resource clocks. A read
    departs the device at ``chan_dep`` = max(die_free,
    chan_free_after_prior_transfers) + sense + retries + xfer; a write
    departs the die at ``die_dep`` (its recorded latency appends the
    transfer it already paid on admission).
    """
    die_dep, die_avail = _queue_departures(
        die_avail0, arrival_ms, die_occ_ms, die, active, n_dies
    )
    chan_arr = jnp.where(rd, die_dep, arrival_ms)
    chan_dep, chan_avail = _queue_departures(
        chan_avail0, chan_arr, xfer_ms, chan, active, n_channels
    )
    return die_dep, chan_dep, die_avail, chan_avail


def lookup(s: st.SSDState, lpns, cfg: geometry.SimConfig):
    """Gather physical metadata + Eq.-3 retry estimate for logical pages."""
    lp = jnp.maximum(lpns, 0)
    slot = s.l2p[lp]
    ok = (lpns >= 0) & (slot >= 0)
    slot = jnp.where(ok, slot, 0)
    blk = slot // cfg.slots_per_block
    mode = s.block_mode[blk]
    age_h = cfg.device_age_h + (s.clock_ms - s.page_write_ms[slot]) / 3.6e6
    retries = retry.page_retries(mode, s.block_pe[blk], age_h, s.block_reads[blk], slot)
    return slot, blk, mode, retries, ok


def write_path_reference(s: st.SSDState, lpns, is_write, cfg: geometry.SimConfig):
    """Sequential user-write path — the original per-request ``lax.scan``.

    Retained purely as the behavioral reference for
    :func:`write_path_batched`; the property tests assert the two produce
    equivalent state on arbitrary mixed traces (DESIGN.md §2A). The engine
    itself always runs the batched path.
    """
    spb = cfg.slots_per_block
    ppb = geometry.pages_per_block(cfg)
    ppb_q = ppb[modes.QLC]
    w_lat_us = modes.WRITE_LATENCY_US[modes.QLC] + cfg.transfer_us

    def wstep(s, x):
        lpn, active = x

        def do(s):
            lun = (lpn % cfg.n_luns).astype(jnp.int32)
            d = s.open_user[lun]
            dd0 = jnp.maximum(d, 0)
            need_new = (d < 0) | (s.block_next[dd0] >= ppb_q)
            a = ftl.alloc_free_block(s, prefer_lun=lun, cfg=cfg)
            d2 = jnp.where(need_new, a, d)
            ok = d2 >= 0
            dd = jnp.maximum(d2, 0)
            # open fresh block in QLC mode
            s = s._replace(
                block_mode=s.block_mode.at[dd].set(
                    jnp.where(ok & need_new, modes.QLC, s.block_mode[dd])
                ),
                block_state=s.block_state.at[dd].set(
                    jnp.where(ok & need_new, st.OPEN, s.block_state[dd])
                ),
                free_count=s.free_count - jnp.where(ok & need_new, 1, 0),
            )
            # invalidate previous mapping
            old = s.l2p[lpn]
            has_old = ok & (old >= 0)
            old_blk = jnp.maximum(old, 0) // spb
            s = s._replace(
                p2l=s.p2l.at[jnp.where(has_old, old, cfg.n_slots)].set(-1, mode="drop"),
                block_valid=s.block_valid.at[jnp.where(has_old, old_blk, s.block_valid.shape[0])].add(
                    -1, mode="drop"
                ),
            )
            slot = dd * spb + s.block_next[dd]
            nxt = s.block_next[dd] + 1
            full = nxt >= ppb_q
            s = s._replace(
                l2p=s.l2p.at[jnp.where(ok, lpn, cfg.n_logical)].set(slot, mode="drop"),
                p2l=s.p2l.at[jnp.where(ok, slot, cfg.n_slots)].set(lpn, mode="drop"),
                page_write_ms=s.page_write_ms.at[jnp.where(ok, slot, cfg.n_slots)].set(
                    s.clock_ms, mode="drop"
                ),
                block_next=s.block_next.at[dd].add(jnp.where(ok, 1, 0)),
                block_valid=s.block_valid.at[dd].add(jnp.where(ok, 1, 0)),
                block_state=s.block_state.at[dd].set(
                    jnp.where(ok & full, st.FULL, s.block_state.at[dd].get())
                ),
                open_user=s.open_user.at[lun].set(jnp.where(ok & ~full, d2, -1)),
                die_busy_ms=s.die_busy_ms.at[lun].add(
                    jnp.where(ok, modes.WRITE_LATENCY_US[modes.QLC] / 1000.0, 0.0)
                ),
                n_writes=s.n_writes + jnp.where(ok, 1.0, 0.0),
                w_lat_hist=telemetry.record(s.w_lat_hist, w_lat_us, ok),
            )
            return s

        return lax.cond(active, do, lambda s_: s_, s), None

    s, _ = lax.scan(wstep, s, (jnp.maximum(lpns, 0), is_write & (lpns >= 0)))
    return s


def write_path_batched(s: st.SSDState, lpns, is_write, cfg: geometry.SimConfig,
                       w_lat_us=None, faults: flt.FaultParams | None = None):
    """Vectorized user-write path (DESIGN.md §2A).

    The chunk's writes are grouped by LUN and assigned destination slots with
    per-LUN prefix sums against ``block_next``; open-block rollovers become a
    small static unroll of allocation *events* (at most
    ``n_luns * ceil(chunk / pages_per_qlc_block)``), replayed in request
    order so allocation decisions match :func:`write_path_reference` exactly.
    All L2P/P2L/timestamp/accounting updates are masked scatters — no
    per-request scan.

    ``w_lat_us`` optionally overrides the per-lane latency recorded in the
    write histogram (the open-loop engine passes queueing-inclusive sojourn
    times); the default is the closed-loop QLC program + transfer constant.

    With ``faults`` active (DESIGN.md §2D), each program draws a
    deterministic failure keyed on (slot, block P/E). A failed program
    wastes its slot (programmed-but-invalid, reclaimed by GC like any stale
    page) and the page data — still in the controller buffer — is re-placed
    through :func:`ftl._place_pages` onto a fresh block, where the program
    is verified-good (real firmware program-verifies the retry target). The
    superseded pre-chunk mapping is invalidated either way: if both the
    program *and* its re-placement fail (free pool exhausted under
    retirement pressure), the write is dropped and counted in
    ``n_dropped_writes`` rather than corrupting the mapping; dropped writes
    still occupy their LUN for a program time, so the queue stalls and the
    Lindley clocks advance instead of the device absorbing infinite load.
    """
    spb = cfg.slots_per_block
    ppb_q = int(geometry.pages_per_block_host(cfg)[modes.QLC])
    C = lpns.shape[0]
    nL, B = cfg.n_luns, cfg.n_blocks
    S, L = cfg.n_slots, cfg.n_logical

    lp = jnp.maximum(lpns, 0)
    w = is_write & (lpns >= 0)
    if faults is not None:
        # spare-pool exhaustion flips the device read-only (DESIGN.md §2D):
        # real drives stop accepting host writes once retirement outruns
        # over-provisioning. Writes in a degraded chunk are dropped whole —
        # counted in ``n_degraded_writes``, never admitted, so no mapping
        # entry is touched and every already-written page stays readable.
        # With an unbounded pool (``spare_blocks < 0``) ``degraded`` is a
        # constant False and the write set is untouched bit for bit.
        degraded = s.spare_count <= jnp.int32(0)
        n_degraded = (w & degraded).sum().astype(jnp.float32)
        w = w & ~degraded
        s = s._replace(n_degraded_writes=s.n_degraded_writes + n_degraded)
    lun = (lp % nL).astype(jnp.int32)

    # per-LUN write ranks via prefix sums
    oh = (lun[:, None] == jnp.arange(nL, dtype=jnp.int32)[None, :]) & w[:, None]
    cum = jnp.cumsum(oh.astype(jnp.int32), axis=0)
    rank = jnp.take_along_axis(cum, lun[:, None], axis=1)[:, 0] - 1
    nw = cum[-1]  # (nL,) writes per LUN

    d0 = s.open_user
    next0 = jnp.where(d0 >= 0, s.block_next[jnp.maximum(d0, 0)], 0)
    avail0 = jnp.where(d0 >= 0, jnp.maximum(ppb_q - next0, 0), 0)

    # ---- allocation events: one per open-block rollover ----
    n_ev = -(-C // ppb_q)  # static: max fresh blocks per LUN per chunk
    E = nL * n_ev
    over = rank - avail0[lun]  # this write's slot count past the open block
    is_trig = w & (over >= 0) & (over % ppb_q == 0)
    ev = lun * n_ev + jnp.clip(over // ppb_q, 0, n_ev - 1)
    pos_i = jnp.arange(C, dtype=jnp.int32)
    trig_pos = (
        jnp.full((E,), C, jnp.int32)
        .at[jnp.where(is_trig, ev, E)]
        .min(pos_i, mode="drop")
    )
    order = jnp.argsort(trig_pos)  # triggered events first, in request order

    dest_ev = jnp.full((E,), -1, jnp.int32)
    for j in range(E):  # static unroll; E is a handful of events
        e = order[j]
        active = trig_pos[e] < C
        a = ftl.alloc_free_block(s, prefer_lun=e // n_ev, cfg=cfg)
        got = active & (a >= 0)
        aa = jnp.maximum(a, 0)
        dest_ev = dest_ev.at[e].set(jnp.where(got, a, -1))
        s = s._replace(
            block_mode=s.block_mode.at[aa].set(
                jnp.where(got, modes.QLC, s.block_mode[aa])
            ),
            block_state=s.block_state.at[aa].set(
                jnp.where(got, st.OPEN, s.block_state[aa])
            ),
            free_count=s.free_count - jnp.where(got, 1, 0),
        )

    # ---- per-write destination slots ----
    in_open = w & (over < 0)
    ev_i = lun * n_ev + jnp.clip(jnp.maximum(over, 0) // ppb_q, 0, n_ev - 1)
    dest_blk = jnp.where(in_open, d0[lun], dest_ev[ev_i])
    off = jnp.where(in_open, next0[lun] + rank, jnp.maximum(over, 0) % ppb_q)
    ok = w & (dest_blk >= 0)
    db = jnp.maximum(dest_blk, 0)
    slot = db * spb + off

    # program-failure draw (DESIGN.md §2D): a failed lane still consumes its
    # slot (programmed-but-invalid) but never maps; its data is re-placed
    # below after the scatters commit
    if faults is not None:
        pfail = ok & flt.prog_fails(
            faults, slot, s.block_pe[db], modes.PE_LIMIT[s.block_mode[db]]
        )
    else:
        pfail = jnp.zeros_like(ok)

    # duplicate LPNs within the chunk: only the last attempted write
    # supersedes the mapping; earlier ones still consume slots and are
    # immediately invalid
    last_pos = (
        jnp.full((L,), -1, jnp.int32)
        .at[jnp.where(ok, lp, L)]
        .max(pos_i, mode="drop")
    )
    is_last = ok & (last_pos[lp] == pos_i)
    mapped = is_last & ~pfail  # last attempt actually decoded into its slot
    refail = is_last & pfail  # last attempt failed -> re-place the data

    # invalidate pre-chunk mappings, once per unique written LPN: the new
    # write supersedes the old data even when its program failed (the fresh
    # copy lives in the controller buffer until re-placed)
    old = s.l2p[lp]
    inv = is_last & (old >= 0)
    old_safe = jnp.maximum(old, 0)

    l2p = s.l2p.at[jnp.where(mapped, lp, L)].set(slot, mode="drop")
    l2p = l2p.at[jnp.where(refail, lp, L)].set(-1, mode="drop")
    p2l = s.p2l.at[jnp.where(ok, slot, S)].set(jnp.where(mapped, lp, -1), mode="drop")
    p2l = p2l.at[jnp.where(inv, old, S)].set(-1, mode="drop")
    pwt = s.page_write_ms.at[jnp.where(ok, slot, S)].set(s.clock_ms, mode="drop")

    oki = ok.astype(jnp.int32)
    bn_add = jax.ops.segment_sum(oki, db, num_segments=B)
    bv_add = jax.ops.segment_sum(mapped.astype(jnp.int32), db, num_segments=B)
    bv_sub = jax.ops.segment_sum(inv.astype(jnp.int32), old_safe // spb, num_segments=B)
    block_next = s.block_next + bn_add
    block_valid = s.block_valid + bv_add - bv_sub
    touched = bn_add > 0
    block_state = jnp.where(
        touched, jnp.where(block_next >= ppb_q, st.FULL, st.OPEN), s.block_state
    )

    # final open-block cursor per LUN (the scan's last-write outcome)
    last_over = (nw - 1) - avail0
    last_ev = jnp.arange(nL, dtype=jnp.int32) * n_ev + jnp.clip(
        jnp.maximum(last_over, 0) // ppb_q, 0, n_ev - 1
    )
    d_last = jnp.where(last_over < 0, d0, dest_ev[last_ev])
    last_full = block_next[jnp.maximum(d_last, 0)] >= ppb_q
    open_user = jnp.where(
        nw > 0, jnp.where((d_last >= 0) & ~last_full, d_last, -1), s.open_user
    )

    okc = jax.ops.segment_sum(oki, lun, num_segments=nL)
    if w_lat_us is None:
        w_lat_us = jnp.full(
            (C,), modes.WRITE_LATENCY_US[modes.QLC] + cfg.transfer_us, jnp.float32
        )
    busy_luns = okc * (modes.WRITE_LATENCY_US[modes.QLC] / 1000.0)
    if faults is not None:
        # graceful degradation: allocation-exhausted writes (retirement
        # pressure emptied the pool) stall their LUN for a program time so
        # the queue backs up instead of the device absorbing infinite load
        drop_alloc = w & ~ok
        busy_luns = busy_luns + jax.ops.segment_sum(
            drop_alloc.astype(jnp.float32), lun, num_segments=nL
        ) * (modes.WRITE_LATENCY_US[modes.QLC] / 1000.0)
    s = s._replace(
        l2p=l2p,
        p2l=p2l,
        page_write_ms=pwt,
        block_next=block_next,
        block_valid=block_valid,
        block_state=block_state,
        open_user=open_user,
        die_busy_ms=s.die_busy_ms + busy_luns,
        n_writes=s.n_writes + ok.sum().astype(jnp.float32),
        w_lat_hist=telemetry.record(s.w_lat_hist, w_lat_us, ok),
    )
    if faults is not None:
        # re-place the data of failed last-attempt programs onto fresh
        # block(s); anything _place_pages could not seat (pool exhausted) is
        # a dropped write — counted, never a corrupted mapping
        s = s._replace(
            n_prog_fails=s.n_prog_fails + pfail.sum().astype(jnp.float32)
        )
        s = ftl._place_pages(s, lp, refail, modes.QLC, cfg, -(-C // ppb_q) + 1)
        still = refail & (s.l2p[lp] < 0)
        n_drop = (drop_alloc.sum() + still.sum()).astype(jnp.float32)
        s = s._replace(n_dropped_writes=s.n_dropped_writes + n_drop)
    return s


def step_chunk(s: st.SSDState, req, cfg: geometry.SimConfig, has_writes: bool,
               knobs: policies.RunKnobs | None = None):
    """One engine step. ``req`` is ``(lpns, ops)`` for the closed-loop model
    or ``(lpns, ops, arrival_ms)`` for the open-loop arrival model. ``knobs``
    optionally supplies traced overrides for the batchable policy/wear knobs
    (sweep runner); ``None`` reads them from ``cfg`` as before."""
    lpns, ops = req[0], req[1]
    arrival = req[2] if len(req) == 3 else None
    is_read = ops == OP_READ
    fp = flt.params_for(cfg, knobs)  # None = no fault ops traced at all
    # chunk-start write counters: the windowed WAF series is the per-chunk
    # delta of (host pages, relocated pages), not the cumulative ratio
    w_c0, r_c0 = s.n_writes, s.n_reloc_pages

    # ---------------- reads (vectorized) ----------------
    slot, blk, mode, retries, ok = lookup(s, lpns, cfg)
    rd = is_read & ok
    svc_us = jnp.where(rd, retry.read_latency_us(mode, retries), 0.0)
    if fp is not None:
        # uncorrectable reads (DESIGN.md §2D): over-budget retry estimates
        # do not decode on-chip — burn the budget, then pay the recovery
        # penalty (flat ECC soft-decode, or a die-parity rebuild when
        # armed). On top of the budget path every read draws a wear-scaled
        # probabilistic uncorrectable (``read_fail_rate``). retries
        # collapses to the budget actually spent only for budget-overs so
        # the retry stats stay truthful; a probabilistic uncorrectable
        # decoded in its estimated retries before the late ECC failure.
        mrr = fp.max_read_retries
        pe_r = s.block_pe[blk]
        rated_r = modes.PE_LIMIT[mode]
        over = rd & (mrr >= 0) & (retries > mrr)
        uncorr = over | (rd & flt.read_fails(fp, slot, pe_r, rated_r))
        retries = jnp.where(over, jnp.maximum(mrr, 0), retries)
        rec_us = flt.recovery_us(fp, mode, cfg)
        svc_us = jnp.where(
            rd,
            retry.read_latency_us(mode, retries)
            + jnp.where(uncorr, rec_us, 0.0),
            0.0,
        )
        # per-lane rebuild mass: the recovery time of uncorrectable lanes
        # recovered via die-parity (split out of the retry component in the
        # obs attribution so rebuild cost is visible on its own). A
        # single-die device has no stripe peers, so parity can never
        # reconstruct there — recovery_us already fell back to the flat
        # penalty and the rebuild lane must stay empty
        if cfg.n_dies > 1:
            is_rb = uncorr & (fp.parity_rebuild > 0)
        else:
            is_rb = jnp.zeros_like(uncorr)
        rb_lane_us = jnp.where(is_rb, rec_us, 0.0)
    else:
        uncorr = None
        rb_lane_us = jnp.zeros_like(svc_us)
    xfer_us = jnp.where(rd, cfg.transfer_us, 0.0)
    die = cfg.die_of_block(blk)
    chan = cfg.channel_of_die(die)

    # ---------------- open-loop queueing (DESIGN.md §2C) ----------------
    if arrival is not None:
        scale = (
            jnp.float32(1.0)
            if knobs is None or knobs.arrival_scale is None
            else knobs.arrival_scale.astype(jnp.float32)
        )
        t_arr = arrival / scale  # scale multiplies the offered rate
        wv = (ops == OP_WRITE) & (lpns >= 0)
        active = rd | wv
        q_die = jnp.where(rd, die, jnp.maximum(lpns, 0) % cfg.n_dies).astype(jnp.int32)
        # die occupancy: sense+retries for reads, page program for writes —
        # the same terms the closed-loop model books into die_busy_ms.
        occ_us = jnp.where(rd, svc_us, modes.WRITE_LATENCY_US[modes.QLC])
        if cfg.chan_model == "lattice":
            # two-resource tandem: sense/program queues on the die, then the
            # page transfer queues on the die's channel bus
            die_dep, chan_dep, die_avail, chan_avail = _tandem_departures(
                s.die_avail_ms, s.chan_avail_ms, t_arr,
                jnp.where(active, occ_us, 0.0) / 1000.0,
                jnp.where(active, cfg.transfer_us, 0.0) / 1000.0,
                q_die, cfg.channel_of_die(q_die), rd, active,
                cfg.n_dies, cfg.n_channels,
            )
            dep_ms = jnp.where(rd, chan_dep, die_dep)
            sojourn_us = jnp.where(
                rd,
                (chan_dep - t_arr) * 1000.0,
                (die_dep - t_arr) * 1000.0 + cfg.transfer_us,
            )
            queue_us = jnp.maximum((die_dep - t_arr) * 1000.0 - occ_us, 0.0)
            chanw_us = jnp.where(
                rd,
                jnp.maximum((chan_dep - die_dep) * 1000.0 - cfg.transfer_us,
                            0.0),
                0.0,
            )
        else:
            # legacy: channel transfer is appended to the recorded latency
            # but does not occupy a resource (it overlaps the next sense)
            dep_ms, die_avail = _queue_departures(
                s.die_avail_ms, t_arr, jnp.where(active, occ_us, 0.0) / 1000.0,
                q_die, active, cfg.n_dies,
            )
            chan_avail = s.chan_avail_ms
            sojourn_us = (dep_ms - t_arr) * 1000.0 + cfg.transfer_us
            queue_us = jnp.maximum(sojourn_us - occ_us - cfg.transfer_us, 0.0)
            chanw_us = jnp.zeros_like(queue_us)
        rec_lat_us = sojourn_us  # queue + sense/retry (or program) + wait + xfer
        chunk_q = jnp.where(rd, queue_us, 0.0).sum() / 1000.0
        chunk_chanw = jnp.where(rd, chanw_us, 0.0).sum() / 1000.0
        chunk_svc = jnp.where(rd, rec_lat_us, 0.0).sum() / 1000.0
        chunk_hist = telemetry.record(
            jnp.zeros((telemetry.N_LAT_BINS,), jnp.float32), rec_lat_us, rd
        )
    else:
        chunk_q = jnp.float32(0.0)
        chunk_chanw = jnp.float32(0.0)
        chunk_svc = (svc_us + xfer_us).sum() / 1000.0
        chunk_hist = telemetry.record(
            jnp.zeros((telemetry.N_LAT_BINS,), jnp.float32), svc_us + xfer_us, rd
        )

    die_add = jax.ops.segment_sum(svc_us, die, num_segments=cfg.n_dies) / 1000.0
    chan_add = jax.ops.segment_sum(xfer_us, chan, num_segments=cfg.n_channels) / 1000.0
    chunk_reads = rd.sum().astype(jnp.float32)
    chunk_retries = jnp.where(rd, retries, 0).sum().astype(jnp.float32)

    s = s._replace(
        die_busy_ms=s.die_busy_ms + die_add,
        chan_busy_ms=s.chan_busy_ms + chan_add,
        block_reads=s.block_reads
        + jax.ops.segment_sum(rd.astype(jnp.int32), blk, num_segments=cfg.n_blocks),
        svc_sum_ms=s.svc_sum_ms + chunk_svc,
        q_sum_ms=s.q_sum_ms + chunk_q,
        chanq_sum_ms=s.chanq_sum_ms + chunk_chanw,
        n_reads=s.n_reads + chunk_reads,
        n_retries=s.n_retries + chunk_retries,
        lat_hist=s.lat_hist + chunk_hist,
    )
    if uncorr is not None:
        # die-parity rebuild accounting (DESIGN.md §2D): every rebuilt lane
        # counts; a second uncorrectable among the stripe peers during the
        # rebuild is true data loss (the sim keeps serving the stale page —
        # no mapping entry is harmed, only the counter records it)
        n_rb = is_rb.sum().astype(jnp.float32)
        if cfg.n_dies > 1:
            loss = is_rb & flt.rebuild_second_fault(
                fp, slot, pe_r, rated_r, cfg.n_dies - 1
            )
            n_dl = loss.sum().astype(jnp.float32)
        else:
            n_dl = jnp.float32(0.0)
        s = s._replace(
            n_uncorrectable=s.n_uncorrectable + uncorr.sum().astype(jnp.float32),
            n_rebuilds=s.n_rebuilds + n_rb,
            n_data_loss=s.n_data_loss + n_dl,
        )

    # ---------------- observability: read-path attribution ----------------
    if obs.enabled(cfg):
        # decompose each recorded read into queue / sense / retry / transfer
        # components; the binning latency is exactly what lat_hist records,
        # so the per-mode count histograms sum back to it bit for bit
        base_us = jnp.where(rd, modes.READ_LATENCY_US[mode], 0.0)
        if arrival is not None:
            q_us = jnp.where(rd, queue_us, 0.0)
            cw_us = jnp.where(rd, chanw_us, 0.0)
            t_read_ms = dep_ms  # window by each read's own departure time
            lat_us = rec_lat_us
        else:
            q_us = jnp.zeros_like(svc_us)
            cw_us = jnp.zeros_like(svc_us)
            t_read_ms = jnp.broadcast_to(s.clock_ms, svc_us.shape)
            lat_us = svc_us + xfer_us
        s = obs.record_reads(
            s, cfg, mode=mode, rd=rd, lat_us=lat_us, queue_us=q_us,
            sense_us=base_us, retry_us=svc_us - base_us - rb_lane_us,
            chanw_us=cw_us, xfer_us=xfer_us, retries=retries, t_ms=t_read_ms,
            uncorr=uncorr, rebuild_us=rb_lane_us,
        )
        obs0 = (s.n_writes, s.n_conversions.sum(), s.n_erases,
                s.n_migrated_pages, s.n_reloc_pages)

    # ---------------- heat update ----------------
    touched = rd | (ops == OP_WRITE)
    heat = hotness.decay_heat(s.heat, cfg.heat)
    heat = heat.at[jnp.where(touched, lpns, cfg.n_logical)].add(1.0, mode="drop")
    s = s._replace(heat=heat)

    # ---------------- user writes ----------------
    if has_writes:
        w_hist0 = s.w_lat_hist
        s = write_path_batched(
            s, lpns, ops == OP_WRITE, cfg,
            w_lat_us=rec_lat_us if arrival is not None else None,
            faults=fp,
        )
        chunk_w_hist = s.w_lat_hist - w_hist0
    else:
        chunk_w_hist = jnp.zeros((telemetry.N_LAT_BINS,), jnp.float32)

    # background FTL work from here on (migrations/reclaim/GC) extends the
    # die availability clocks: the next chunk's arrivals queue behind it
    busy_mark = s.die_busy_ms

    # die-parity rebuild peer charges (DESIGN.md §2D): each rebuilt read
    # senses the stripe's peer dies and moves their pages over the channel
    # buses. The victim lane already carries the critical path in its own
    # recorded latency (``recovery_us``); here the *peer* resources are
    # charged on the timing lattice like any background work — a sense per
    # peer die, a transfer per peer page on its channel — so subsequent
    # arrivals queue behind the rebuild. With ``parity_rebuild`` off (or a
    # one-die geometry) every charge is exactly 0.0 and the clocks are
    # untouched bit for bit.
    if fp is not None and cfg.n_dies > 1:
        rb_sense_us = jnp.where(is_rb, modes.READ_LATENCY_US[mode], 0.0)
        own_sense = jax.ops.segment_sum(rb_sense_us, die,
                                        num_segments=cfg.n_dies)
        rb_die_ms = (rb_sense_us.sum() - own_sense) / 1000.0
        n_rb_chan = jax.ops.segment_sum(
            is_rb.astype(jnp.float32), chan, num_segments=cfg.n_channels
        )
        rb_chan_ms = (
            (is_rb.sum().astype(jnp.float32) * cfg.luns_per_channel - n_rb_chan)
            * cfg.transfer_us
        ) / 1000.0
        s = s._replace(
            die_busy_ms=s.die_busy_ms + rb_die_ms,
            chan_busy_ms=s.chan_busy_ms + rb_chan_ms,
        )
        if arrival is not None and cfg.chan_model == "lattice":
            chan_avail = chan_avail + rb_chan_ms

    # ---------------- policy: conversion migrations ----------------
    if cfg.policy != geometry.BASELINE:
        # dedup of the chunk's read set: one int32 sort + adjacent-equal
        # mask. Replaces jnp.unique(size=chunk) (~9x slower: it layers
        # cumsum/scatter compaction on top of the same sort). Masked lanes
        # sort to the top as n_logical and drop to -1; survivors stay in
        # ascending LPN order, so heat ties in the top-k below break
        # identically to the jnp.unique ordering. (A sort-free scatter-mark
        # on an (L,)-sized scratch was measured slower: the per-chunk fill
        # of the scratch dominates at real geometry.)
        srt = jnp.sort(jnp.where(rd, lpns, cfg.n_logical))
        dup = jnp.concatenate([jnp.zeros((1,), bool), srt[1:] == srt[:-1]])
        uniq = jnp.where((srt >= cfg.n_logical) | dup, -1, srt)
        slot_u, blk_u, mode_u, retr_u, ok_u = lookup(s, uniq, cfg)
        heat_u = s.heat[jnp.maximum(uniq, 0)]
        sel = policies.select_migrations(
            cfg, uniq, mode_u, retr_u, heat_u, ok_u, s.block_pe[blk_u], knobs=knobs
        )
        for tgt in (modes.SLC, modes.TLC):
            s = ftl.maybe_migrate_pages(s, sel[tgt], tgt, cfg, faults=fp)

        # ---------------- elastic capacity recovery ----------------
        if cfg.reclaim_enabled:
            cls_rd = hotness.classify(s.heat[jnp.maximum(lpns, 0)], cfg.heat)
            hw = rd & (cls_rd >= modes.WARM)
            touched_blk = (
                jax.ops.segment_max(
                    hw.astype(jnp.int32), blk, num_segments=cfg.n_blocks
                )
                > 0
            )
            s = s._replace(
                block_cold_age=jnp.where(touched_blk, 0, s.block_cold_age + 1)
            )
            free_frac = ftl.free_block_count(s) / cfg.n_blocks
            rcfg = reclaim.ReclaimConfig(max_per_pass=cfg.max_conversions_per_chunk)

            def _reclaim_pass(s):
                # Per-block residual heat = max heat over the block's valid
                # pages (the demotion tie-breaker: among equally long-cold
                # blocks, the one with the least residual heat demotes
                # first). The full-device segment_max is hoisted here so it
                # runs once per pass and — via the pressure cond below — only
                # when a demotion can actually fire.
                slot_blk = (
                    jnp.arange(cfg.n_slots, dtype=jnp.int32) // cfg.slots_per_block
                )
                page_heat = jnp.where(s.p2l >= 0, s.heat[jnp.maximum(s.p2l, 0)], 0.0)
                block_heat = jnp.maximum(
                    jax.ops.segment_max(page_heat, slot_blk, num_segments=cfg.n_blocks),
                    0.0,
                )
                victims, v_ok, v_tgt = reclaim.score_victims(
                    s, cfg, reclaim.DEMOTION, block_heat=block_heat,
                    free_frac=free_frac, reclaim_cfg=rcfg,
                )
                return ftl.reclaim_victims(s, victims, v_ok, v_tgt, cfg,
                                           faults=fp)

            s = lax.cond(
                free_frac < rcfg.low_watermark, _reclaim_pass, lambda s_: s_, s
            )

    # ---------------- GC (fused multi-victim, deficit-aware) ----------------
    s = ftl.gc_step(s, cfg, faults=fp, knobs=knobs)

    # clock follows the busiest die (device saturated under FIO load)
    s = s._replace(clock_ms=jnp.maximum(s.clock_ms, s.die_busy_ms.max()))

    if arrival is not None:
        # block the next chunk's arrivals behind this chunk's background
        # work, and let wall time follow real arrivals (idle gaps age pages)
        die_avail = die_avail + (s.die_busy_ms - busy_mark)
        s = s._replace(
            die_avail_ms=die_avail,
            chan_avail_ms=chan_avail,
            clock_ms=jnp.maximum(
                s.clock_ms,
                jnp.maximum(t_arr[-1],
                            jnp.maximum(die_avail.max(), chan_avail.max())),
            ),
        )

    # ---------------- observability: per-window chunk series ----------------
    if obs.enabled(cfg):
        s = obs.record_chunk(
            s, cfg, t_ms=s.clock_ms,
            writes=s.n_writes - obs0[0],
            conversions=s.n_conversions.sum() - obs0[1],
            erases=s.n_erases - obs0[2],
            migrated=s.n_migrated_pages - obs0[3],
            reloc=s.n_reloc_pages - obs0[4],
        )

    nonfree = s.block_state != st.FREE
    mode_hist = jax.ops.segment_sum(
        nonfree.astype(jnp.int32), s.block_mode, num_segments=3
    )
    y = ChunkMetrics(
        capacity_pages=st.usable_capacity_pages(s, cfg),
        free_blocks=ftl.free_block_count(s),
        mode_hist=mode_hist,
        reads=chunk_reads,
        retries=chunk_retries,
        svc_ms=chunk_svc,
        migrated=s.n_migrated_pages,
        lat_hist=chunk_hist,
        w_lat_hist=chunk_w_hist,
        q_ms=chunk_q,
        chanq_ms=chunk_chanw,
        user_pages=s.n_writes - w_c0,
        reloc_pages=s.n_reloc_pages - r_c0,
    )
    return s, y


@partial(jax.jit, static_argnums=(0, 3))
def _run_jit(cfg: geometry.SimConfig, lpns, ops, has_writes: bool):
    s0 = st.init_state(cfg)

    def body(s, x):
        return step_chunk(s, x, cfg, has_writes)

    return lax.scan(body, s0, (lpns, ops))


@partial(jax.jit, static_argnums=(0, 4))
def _run_open_jit(cfg: geometry.SimConfig, lpns, ops, arrival_ms,
                  has_writes: bool):
    s0 = st.init_state(cfg)

    def body(s, x):
        return step_chunk(s, x, cfg, has_writes)

    return lax.scan(body, s0, (lpns, ops, arrival_ms))


def run(cfg: geometry.SimConfig, trace, has_writes: bool | None = None):
    """Run a full trace. ``trace`` is a dict with 'lpn' and 'op' arrays of
    shape (n_chunks, cfg.chunk); an optional 'arrival_ms' array of the same
    shape switches the engine to the open-loop arrival model. Returns
    (final_state, ChunkMetrics stacked).
    """
    if has_writes is None:
        has_writes = bool((trace["op"] == OP_WRITE).any())
    lpns = jnp.asarray(trace["lpn"], jnp.int32)
    ops = jnp.asarray(trace["op"], jnp.int32)
    if "arrival_ms" in trace:
        arr = jnp.asarray(trace["arrival_ms"], jnp.float32)
        return _run_open_jit(cfg, lpns, ops, arr, has_writes)
    return _run_jit(cfg, lpns, ops, has_writes)


def summarize(s: st.SSDState, cfg: geometry.SimConfig, threads: int = 4):
    """Headline numbers for the paper's figures.

    Every value is JSON-safe (floats and nested lists only — no ndarrays,
    no nested dicts): the sweep runner writes the dict straight to
    ``summaries.json`` and ``assert_results_identical`` np.asarray's each
    value, so both representations must round-trip."""
    import numpy as np

    n_reads = float(s.n_reads)
    # under the open-loop model elapsed time is the last die- (or, lattice,
    # channel-) availability clock (includes idle gaps); closed-loop
    # die_avail_ms/chan_avail_ms stay 0 so the busy-time makespan is
    # unchanged. Host-side numpy on purpose: the sweep runner hands this
    # function device_get'ed numpy leaves and summarize must not enqueue
    # device work behind them (DESIGN.md §7.3).
    makespan_ms = float(
        max(np.max(s.die_busy_ms), np.max(s.chan_busy_ms),
            np.max(s.die_avail_ms), np.max(s.chan_avail_ms))
    )
    mean_lat_ms = float(s.svc_sum_ms) / max(n_reads, 1.0)
    if threads == 1:
        # synchronous single-thread: no inter-LUN overlap; background work
        # (migrations/GC) still steals device time via the makespan term.
        iops = 1000.0 / mean_lat_ms if mean_lat_ms > 0 else 0.0
    else:
        iops = n_reads / max(makespan_ms / 1000.0, 1e-9)
    cap = float(st.capacity_gib(s, cfg, xp=np))
    init_cap = cfg.n_blocks * cfg.slots_per_block * cfg.page_bytes / 2**30
    pct = telemetry.percentiles(s.lat_hist)
    wpct = telemetry.percentiles(s.w_lat_hist)
    # ---- endurance / WAF telemetry (DESIGN.md §2E) ----
    user_pages = float(s.n_writes)
    reloc_pages = float(s.n_reloc_pages)
    waf = (user_pages + reloc_pages) / user_pages if user_pages > 0 else 1.0
    block_pe = np.asarray(s.block_pe, np.float64)
    live = ~np.asarray(s.block_bad)
    pe_live = block_pe[live] if live.any() else block_pe
    block_mode_h = np.asarray(s.block_mode)
    pe_mean_by_mode = []
    for m in range(modes.N_MODES):
        sel = live & (block_mode_h == m)
        pe_mean_by_mode.append(float(block_pe[sel].mean()) if sel.any() else 0.0)
    # lifetime projection: rated QLC endurance (the device's native mode)
    # over the observed host write rate, discounted by the measured WAF
    cap_bytes = cap * 2**30
    tbw = modes.tbw_bytes(cap_bytes, modes.RATED_PE[modes.QLC], waf)
    host_bytes_per_day = (user_pages * cfg.page_bytes
                          / max(makespan_ms, 1e-9) * 86_400_000.0)
    # ---- spare pool / degraded-mode accounting (DESIGN.md §2D) ----
    pool_total = int(s.spare_total)
    bounded = pool_total < 2**30  # st.SPARE_UNLIMITED sentinel
    spares_total = float(pool_total) if bounded else -1.0
    spares_remaining = float(s.spare_count) if bounded else -1.0
    qlc_ppb = int(geometry.pages_per_block_host(cfg)[modes.QLC])
    spare_covered_gib = (
        min(float(s.bad_count), float(pool_total)) * qlc_ppb * cfg.page_bytes
        / 2**30
        if bounded
        else float(s.bad_count) * qlc_ppb * cfg.page_bytes / 2**30
    )
    degraded_flag = 1.0 if bounded and int(s.spare_count) <= 0 else 0.0
    return dict(
        iops=iops,
        mean_read_latency_us=mean_lat_ms * 1000.0,
        read_lat_p50_us=pct[0.5],
        read_lat_p95_us=pct[0.95],
        read_lat_p99_us=pct[0.99],
        read_lat_p999_us=pct[0.999],
        write_lat_p50_us=wpct[0.5],
        write_lat_p95_us=wpct[0.95],
        write_lat_p99_us=wpct[0.99],
        write_lat_p999_us=wpct[0.999],
        read_queue_delay_us=float(s.q_sum_ms) / max(n_reads, 1.0) * 1000.0,
        read_chan_wait_us=float(s.chanq_sum_ms) / max(n_reads, 1.0) * 1000.0,
        retries_per_read=float(s.n_retries) / max(n_reads, 1.0),
        capacity_gib=cap,
        capacity_loss_gib=init_cap - cap,
        migrated_pages=float(s.n_migrated_pages),
        erases=float(s.n_erases),
        conversions=np.asarray(s.n_conversions).tolist(),
        reads=n_reads,
        writes=float(s.n_writes),
        # fault / recovery accounting (DESIGN.md §2D); all exactly 0.0 when
        # fault injection is off
        uncorrectable_reads=float(s.n_uncorrectable),
        prog_fails=float(s.n_prog_fails),
        erase_fails=float(s.n_erase_fails),
        dropped_writes=float(s.n_dropped_writes),
        bad_blocks=float(s.bad_count),
        # wear / rebuild / spare-pool accounting (DESIGN.md §2D): spares_*
        # report -1.0 for an unbounded pool; ``spare_covered_gib`` is the
        # retired capacity the over-provisioning pool backfills, so
        # ``effective_capacity_gib`` is what the host still sees
        rebuilds=float(s.n_rebuilds),
        data_loss=float(s.n_data_loss),
        degraded_writes=float(s.n_degraded_writes),
        spares_total=spares_total,
        spares_remaining=spares_remaining,
        spare_covered_gib=spare_covered_gib,
        effective_capacity_gib=cap + spare_covered_gib,
        degraded=degraded_flag,
        # endurance / WAF (DESIGN.md §2E); waf pins to 1.0 and
        # lifetime_years to 0.0 when the run had no host writes
        user_pages=user_pages,
        reloc_pages=reloc_pages,
        waf=waf,
        pe_mean=float(pe_live.mean()),
        pe_variance=float(pe_live.var()),
        pe_max=float(pe_live.max()),
        pe_mean_by_mode=pe_mean_by_mode,
        tbw_gib=tbw / 2**30,
        dwpd=modes.dwpd(host_bytes_per_day, cap_bytes) if user_pages > 0 else 0.0,
        lifetime_years=(modes.lifetime_years(tbw, host_bytes_per_day)
                        if user_pages > 0 else 0.0),
        **obs.summary(s, cfg),
    )
