"""FTL mechanics: block allocation, block-granularity migration/conversion
(paper Fig. 8-10), fused multi-victim GC, fused reclaim demotion. Everything
is jit-safe with static shapes; per-block operations work on the block's
fixed slots_per_block window.

Background block relocation — GC relocation, reclaim demotion and block
conversion — is ONE code path (DESIGN.md §2A): :func:`relocate_group`
gathers the victims' valid pages, books their Eq.-3 read cost, places them
through the shared :func:`_place_pages` core and erases every victim in one
vectorized :func:`_erase_many`. The original scalar single-victim path
survives only as ``gc_pass_reference`` / ``_migrate_block_reference`` (the
behavioral reference for the bit-identity tests, like
``engine.write_path_reference``).

Scatter discipline: masked-out lanes are redirected to an out-of-range index
and dropped (``mode='drop'``) — never write a dummy in-range index, because
duplicate-index ``set`` conflicts are unordered in XLA.

Free-pool bookkeeping (DESIGN.md §2A): ``SSDState.free_count`` is the exact
number of FREE blocks, incremented per erased victim by ``_erase_many`` and
decremented at the two places a FREE block is opened (``_place_pages`` and
the engine write path). ``SSDState.free_hint`` holds one candidate free
block per LUN, refreshed on erase; ``alloc_free_block`` trusts a hint only
after re-checking ``block_state`` and falls back to the O(n_blocks) scan
when no hint is live.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import faults as flt
from repro.core import modes, reclaim, retry
from repro.ssdsim import geometry, obs, state as st

# Max destination blocks one conversion can need: one partially-filled open
# migration block plus ceil(1024/256) = 4 fresh SLC blocks.
MAX_DEST = 5


# upper bound on per-block P/E for the youngest-first composite key: the
# die-affinity bonus must dominate any wear difference, so P/E clips here
_ALLOC_PE_CAP = 1 << 22


def _alloc_scan(s: st.SSDState, prefer_lun=None, cfg: geometry.SimConfig | None = None):
    """Full block_state scan (slow path): free block, prefer matching LUN."""
    free = s.block_state == st.FREE
    if prefer_lun is not None:
        blk = jnp.arange(s.block_mode.shape[0], dtype=jnp.int32)
        lun_match = cfg.die_of_block(blk) == prefer_lun
        score = free.astype(jnp.int32) * 2 + (free & lun_match).astype(jnp.int32)
    else:
        score = free.astype(jnp.int32)
    idx = jnp.argmax(score).astype(jnp.int32)
    return jnp.where(score[idx] > 0, idx, -1)


def _alloc_scan_youngest(s: st.SSDState, prefer_lun=None,
                         cfg: geometry.SimConfig | None = None):
    """Wear-levelled scan: the lowest-P/E free block, die affinity first.

    Composite argmin key ``mismatch * CAP + pe`` — a die-matching block
    always beats a mismatched one, wear breaks the tie within each class,
    and ``argmin`` resolves equal wear to the lowest block id (the same
    tie-break the lowest-id scan uses)."""
    free = s.block_state == st.FREE
    pe = jnp.clip(s.block_pe, 0, _ALLOC_PE_CAP - 1)
    if prefer_lun is not None:
        blk = jnp.arange(s.block_mode.shape[0], dtype=jnp.int32)
        mismatch = (cfg.die_of_block(blk) != prefer_lun).astype(jnp.int32)
        key = mismatch * _ALLOC_PE_CAP + pe
    else:
        key = pe
    key = jnp.where(free, key, jnp.iinfo(jnp.int32).max)
    idx = jnp.argmin(key).astype(jnp.int32)
    return jnp.where(free[idx], idx, -1)


def alloc_free_block(s: st.SSDState, prefer_lun=None, cfg: geometry.SimConfig | None = None):
    """Index of a free block (prefer matching LUN), or -1 if none.

    O(1) fast path through the per-LUN free hints; the hint is validated
    against ``block_state`` (hints go stale when consumed) and the full scan
    runs only when it is dead. With ``prefer_lun`` only that LUN's hint is
    trusted, so LUN affinity is never worse than the scan's.

    ``cfg.alloc_policy == "youngest"`` (wear-levelled allocation) always
    takes the full scan — a hint is *a* free block on the die, not the
    youngest one — picking the lowest-P/E free block with die affinity
    intact. The default ``"lowest_id"`` path is untouched (pinned
    bit-identical by tests/test_wearout.py).
    """
    if cfg is not None and cfg.alloc_policy == "youngest":
        return _alloc_scan_youngest(s, prefer_lun, cfg)
    hints = s.free_hint
    live = (hints >= 0) & (s.block_state[jnp.maximum(hints, 0)] == st.FREE)
    if prefer_lun is not None:
        h = hints[prefer_lun]
        hit = live[prefer_lun]
    else:
        j = jnp.argmax(live)
        h = hints[j]
        hit = live[j]
    return lax.cond(
        hit,
        lambda: h.astype(jnp.int32),
        lambda: _alloc_scan(s, prefer_lun, cfg),
    )


def free_block_count(s: st.SSDState):
    """Exact FREE-block count, O(1) via the incremental bookkeeping."""
    return s.free_count


def _book_rebuilds(s: st.SSDState, faults: flt.FaultParams, uncorr, slots,
                   pe, rated, cfg: geometry.SimConfig):
    """Book one batch of uncorrectable reads: count them and, with parity
    rebuild armed, the stripe reconstructions they trigger plus any
    second-fault data loss among the peer reads (DESIGN.md §2D). Shared by
    the background relocation readers; the engine's user read path performs
    the same accounting inline (it additionally charges the peer dies on
    the timing lattice)."""
    n_unc = uncorr.sum().astype(jnp.float32)
    on = faults.parity_rebuild > 0
    if cfg.n_dies > 1:
        n_rb = jnp.where(on, n_unc, 0.0)
        loss = uncorr & on & flt.rebuild_second_fault(
            faults, slots, pe, rated, cfg.n_dies - 1)
        n_dl = loss.sum().astype(jnp.float32)
    else:  # no stripe peers -> no rebuild, no loss
        n_rb = jnp.float32(0.0)
        n_dl = jnp.float32(0.0)
    return s._replace(
        n_uncorrectable=s.n_uncorrectable + n_unc,
        n_rebuilds=s.n_rebuilds + n_rb,
        n_data_loss=s.n_data_loss + n_dl,
    )


def _erase_many(s: st.SSDState, victims, grp, cfg: geometry.SimConfig,
                faults: flt.FaultParams | None = None):
    """Erase every ``grp``-masked victim block in one vectorized pass:
    masked per-victim slot-window clears for ``p2l``, masked per-block
    scatters reset the block metadata, a ``segment_sum`` books per-LUN
    erase latency, and a per-LUN "any erased block" reduction refreshes
    ``free_hint``.

    The single production erase primitive (GC, reclaim and conversion all
    reach it through :func:`relocate_group`); bit-identical to the scalar
    ``_erase`` reference for a single victim, and ~2x cheaper than the K
    sequential ``lax.cond(_erase)`` scatters it replaced. The ``p2l`` clear
    is a static unroll of masked ``dynamic_update_slice`` windows rather
    than one K*spb-index scatter: each victim's slots are contiguous, and
    on XLA:CPU a slice memcpy beats the general per-element scatter by ~4x
    (a masked-out lane writes its current window back, a no-op).

    With ``faults`` active (DESIGN.md §2D), each attempted erase draws a
    deterministic failure keyed on (block, P/E): a failed block is retired
    to ``BAD`` / ``block_bad`` instead of returning to the free pool — it
    never becomes an allocation hint, never counts toward ``free_count``,
    and ``alloc_free_block`` skips it forever (the scan only matches
    ``FREE``). The erase latency and P/E bump are still paid (the op was
    attempted) and the slot/metadata clears still run, so a retired block
    carries no mapped pages — exactly what ``check_invariants`` asserts.
    """
    spb = cfg.slots_per_block
    B = s.block_mode.shape[0]
    vb = jnp.maximum(victims, 0)
    bdrop = jnp.where(grp, vb, B)  # B = out of range -> dropped
    p2l = s.p2l
    neg = jnp.full((spb,), -1, jnp.int32)
    for i in range(victims.shape[0]):
        cur = lax.dynamic_slice(p2l, (vb[i] * spb,), (spb,))
        p2l = lax.dynamic_update_slice(
            p2l, jnp.where(grp[i], neg, cur), (vb[i] * spb,)
        )
    die = cfg.die_of_block(vb)
    erase_ms = jnp.where(grp, modes.ERASE_LATENCY_US[s.block_mode[vb]] / 1000.0, 0.0)
    if cfg.chan_model == "lattice" and cfg.planes_per_lun > 1:
        # multi-plane erase overlap: co-scheduled plane erases on one die
        # pay the max of the per-plane times, not their sum
        per_plane = jax.ops.segment_sum(
            erase_ms, cfg.plane_slot_of_block(vb),
            num_segments=cfg.n_dies * cfg.planes_per_die,
        )
        die_erase = per_plane.reshape(cfg.n_dies, cfg.planes_per_die).max(1)
    else:
        die_erase = jax.ops.segment_sum(erase_ms, die, num_segments=cfg.n_dies)
    if faults is not None:
        fail = grp & flt.erase_fails(
            faults, flt.block_entity(vb, cfg.n_dies, cfg.planes_per_die),
            s.block_pe[vb], modes.PE_LIMIT[s.block_mode[vb]],
        )
    else:
        fail = jnp.zeros_like(grp)
    freed = grp & ~fail
    # any *freed* block on the die is a valid allocation hint; take the max
    # id (retired blocks must never become hints)
    hint_cand = jax.ops.segment_max(
        jnp.where(freed, vb, -1), die, num_segments=cfg.n_dies
    )
    n_free = freed.sum().astype(jnp.int32)
    n_fail = fail.sum().astype(jnp.int32)
    src_mode = s.block_mode[vb]
    s = s._replace(
        p2l=p2l,
        block_pe=s.block_pe.at[bdrop].add(1, mode="drop"),
        block_reads=s.block_reads.at[bdrop].set(0, mode="drop"),
        block_state=s.block_state.at[bdrop].set(
            jnp.where(fail, st.BAD, st.FREE).astype(s.block_state.dtype),
            mode="drop",
        ),
        block_next=s.block_next.at[bdrop].set(0, mode="drop"),
        block_valid=s.block_valid.at[bdrop].set(0, mode="drop"),
        block_cold_age=s.block_cold_age.at[bdrop].set(0, mode="drop"),
        block_bad=s.block_bad.at[jnp.where(fail, vb, B)].set(True, mode="drop"),
        bad_count=s.bad_count + n_fail,
        # each retirement consumes an over-provisioning spare until the pool
        # runs dry (invariant: spare_count == max(total - bad, 0))
        spare_count=jnp.maximum(s.spare_count - n_fail, 0),
        free_count=s.free_count + n_free,
        free_hint=jnp.where(hint_cand >= 0, hint_cand.astype(jnp.int32), s.free_hint),
        die_busy_ms=s.die_busy_ms + die_erase,
        n_erases=s.n_erases + grp.sum().astype(jnp.float32),
        n_erase_fails=s.n_erase_fails + n_fail.astype(jnp.float32),
    )
    if faults is not None and obs.full(cfg):
        zeros = jnp.zeros(vb.shape, jnp.float32)
        s = obs.record_events(
            s, cfg,
            mask=fail,
            block=vb,
            from_mode=src_mode,
            to_mode=src_mode,
            reason=obs.REASON_BAD_BLOCK,
            retry_est=zeros,
            pages=zeros,
        )
    return s


def _erase(s: st.SSDState, blk, cfg: geometry.SimConfig):
    """Erase ``blk``: invalidate slots, bump P/E, return to free pool.

    Reference-only (the sequential half of ``_migrate_block_reference``);
    production relocation erases through :func:`_erase_many`.
    """
    spb = cfg.slots_per_block
    mode = s.block_mode[blk]
    p2l = lax.dynamic_update_slice(s.p2l, jnp.full((spb,), -1, jnp.int32), (blk * spb,))
    die = cfg.die_of_block(blk)
    erase_ms = modes.ERASE_LATENCY_US[mode] / 1000.0
    return s._replace(
        p2l=p2l,
        block_pe=s.block_pe.at[blk].add(1),
        block_reads=s.block_reads.at[blk].set(0),
        block_state=s.block_state.at[blk].set(st.FREE),
        block_next=s.block_next.at[blk].set(0),
        block_valid=s.block_valid.at[blk].set(0),
        block_cold_age=s.block_cold_age.at[blk].set(0),
        free_count=s.free_count + 1,
        free_hint=s.free_hint.at[die].set(blk.astype(jnp.int32)),
        die_busy_ms=s.die_busy_ms.at[die].add(erase_ms),
        n_erases=s.n_erases + 1.0,
    )


def _place_pages(s: st.SSDState, lpns, valid, tgt_mode, cfg: geometry.SimConfig,
                 n_dest: int):
    """Append the ``valid``-masked ``lpns`` into open migration block(s) of
    ``tgt_mode``, opening up to ``n_dest`` fresh blocks from the free pool.

    Shared placement core of page migration and the fused relocation kernel
    — besides the engine write path this is the only place FREE blocks are
    consumed, so the free-pool bookkeeping lives here once. Callers
    invalidate (or erase) the source slots themselves.

    The ``n_dest`` unroll carries only scalar per-block bookkeeping
    (allocation, block_next/valid/state, busy time) and accumulates each
    lane's destination slot; every lane is placed in exactly one iteration,
    so the expensive full-array scatters (l2p/p2l/page timestamps) happen
    once after the loop instead of once per destination — the unroll cost
    no longer scales with the lane count.
    """
    spb = cfg.slots_per_block
    ppb = geometry.pages_per_block(cfg)
    S, L = cfg.n_slots, cfg.n_logical

    lp_safe = jnp.maximum(lpns, 0)
    pos = jnp.cumsum(valid.astype(jnp.int32)) - 1  # rank of each valid page
    n_valid = valid.sum()
    consumed = jnp.int32(0)
    dest_slot = jnp.full(lpns.shape, S, jnp.int32)  # S = dropped
    # lattice multi-plane overlap: defer the program charges and fold
    # co-scheduled plane programs on one die to their max after the unroll
    # (legacy — and any single-plane geometry — keeps the sequential
    # per-iteration adds, preserving float association bit for bit)
    overlap = cfg.chan_model == "lattice" and cfg.planes_per_lun > 1
    prog_blocks: list = []
    prog_ms: list = []
    for _ in range(n_dest):
        cur = s.open_mig[tgt_mode]
        fresh = cur < 0
        a = alloc_free_block(s, cfg=cfg)
        d = jnp.where(fresh, a, cur)
        dd = jnp.maximum(d, 0)  # safe index; all writes masked when d < 0
        start = s.block_next[dd]
        usable = jnp.where(d >= 0, ppb[tgt_mode] - start, 0)
        take = jnp.clip(n_valid - consumed, 0, usable)
        opened = (take > 0) & (d >= 0)
        sel = valid & (pos >= consumed) & (pos < consumed + take) & opened
        dest_slot = jnp.where(sel, dd * spb + start + (pos - consumed), dest_slot)

        write_ms = take * modes.WRITE_LATENCY_US[tgt_mode] / 1000.0
        is_full = start + take >= ppb[tgt_mode]
        if overlap:
            prog_blocks.append(dd)
            prog_ms.append(write_ms)
            busy = s.die_busy_ms
        else:
            busy = s.die_busy_ms.at[cfg.die_of_block(dd)].add(write_ms)
        s = s._replace(
            block_mode=s.block_mode.at[dd].set(
                jnp.where(opened, tgt_mode, s.block_mode[dd])
            ),
            block_state=s.block_state.at[dd].set(
                jnp.where(opened, jnp.where(is_full, st.FULL, st.OPEN),
                          s.block_state[dd])
            ),
            free_count=s.free_count - jnp.where(opened & fresh, 1, 0),
            block_next=s.block_next.at[dd].add(jnp.where(opened, take, 0)),
            block_valid=s.block_valid.at[dd].add(jnp.where(opened, take, 0)),
            open_mig=s.open_mig.at[tgt_mode].set(
                jnp.where(opened, jnp.where(is_full, -1, d), s.open_mig[tgt_mode])
            ),
            die_busy_ms=busy,
        )
        consumed = consumed + take
    if overlap and prog_blocks:
        per_plane = jax.ops.segment_sum(
            jnp.stack(prog_ms), cfg.plane_slot_of_block(jnp.stack(prog_blocks)),
            num_segments=cfg.n_dies * cfg.planes_per_die,
        )
        die_prog = per_plane.reshape(cfg.n_dies, cfg.planes_per_die).max(1)
        s = s._replace(die_busy_ms=s.die_busy_ms + die_prog)
    placed = dest_slot < S
    lp_idx = jnp.where(placed, lpns, L)  # L = dropped
    return s._replace(
        l2p=s.l2p.at[lp_idx].set(dest_slot, mode="drop"),
        p2l=s.p2l.at[dest_slot].set(lp_safe, mode="drop"),
        page_write_ms=s.page_write_ms.at[dest_slot].set(s.clock_ms, mode="drop"),
        # every physical relocation program is a page of write amplification;
        # counting here (the single placement core) covers GC, reclaim,
        # conversion AND prog-fail re-placement with one counter
        n_reloc_pages=s.n_reloc_pages + placed.sum().astype(jnp.float32),
    )


def migrate_block(s: st.SSDState, src, tgt_mode, cfg: geometry.SimConfig,
                  faults: flt.FaultParams | None = None):
    """Move all valid pages of ``src`` into open migration block(s) of
    ``tgt_mode``, then erase ``src``. This is both mode conversion
    (tgt != src mode) and GC relocation (tgt == src mode) — a K=1 call into
    the fused :func:`relocate_group` kernel.

    Latency accounting: each valid page costs one source-mode read (with its
    Eq.-3 retry count) plus one target-mode program; the erase costs the
    source-mode erase latency. Requires up to MAX_DEST destination blocks;
    the caller guards on free_block_count.
    """
    victims = jnp.asarray(src, jnp.int32).reshape((1,))
    return relocate_group(s, victims, jnp.ones((1,), bool), tgt_mode, cfg,
                          MAX_DEST, reason=obs.REASON_CONV_BLOCK,
                          faults=faults)


def _migrate_block_reference(s: st.SSDState, src, tgt_mode, cfg: geometry.SimConfig):
    """The original sequential block migration — retained purely as the
    behavioral reference for the fused-kernel bit-identity tests
    (``gc_pass_reference`` routes through it); production code uses
    :func:`migrate_block` / :func:`relocate_group`.
    """
    spb = cfg.slots_per_block

    src_mode = s.block_mode[src]
    slots = src * spb + jnp.arange(spb, dtype=jnp.int32)
    lpns = lax.dynamic_slice(s.p2l, (src * spb,), (spb,))
    valid = lpns >= 0
    n_valid = valid.sum()

    # -- read cost of the source pages (Eq. 1 -> Eq. 3 per page) --
    age_h = (
        cfg.device_age_h
        + (s.clock_ms - lax.dynamic_slice(s.page_write_ms, (src * spb,), (spb,))) / 3.6e6
    )
    retries = retry.page_retries(src_mode, s.block_pe[src], age_h, s.block_reads[src], slots)
    read_ms = jnp.where(valid, retry.read_latency_us(src_mode, retries), 0.0).sum() / 1000.0
    src_die = cfg.die_of_block(src)
    s = s._replace(die_busy_ms=s.die_busy_ms.at[src_die].add(read_ms))

    # source slots die with the erase below; no explicit invalidation needed
    s = _place_pages(s, lpns, valid, tgt_mode, cfg, MAX_DEST)

    s = s._replace(
        n_migrated_pages=s.n_migrated_pages + n_valid,
        n_conversions=s.n_conversions.at[src_mode, tgt_mode].add(1.0),
    )
    return _erase(s, src, cfg)


def _dest_unroll(cfg: geometry.SimConfig, n_pages: int) -> int:
    """Destination blocks needed to place ``n_pages`` into the smallest-
    capacity mode (SLC), plus one partially-filled open block."""
    slc_ppb = max(cfg.slots_per_block // 4, 1)
    return -(-n_pages // slc_ppb) + 1


def migrate_pages(s: st.SSDState, lpns, tgt_mode, cfg: geometry.SimConfig,
                  faults: flt.FaultParams | None = None):
    """Page-granular conversion migration (paper Fig. 9/10): move the given
    logical pages into open block(s) programmed in ``tgt_mode``, invalidating
    their old slots. The destination block is the unit of mode uniformity
    ("flash type alignment"); source blocks are compacted later by GC.

    ``lpns``: (M,) int32, -1-padded. M is static (cfg.migrate_pages_per_chunk).
    With ``faults`` active, over-budget migration reads pay the ECC recovery
    penalty and count as uncorrectable (same model as :func:`relocate_group`).
    """
    spb = cfg.slots_per_block
    S = cfg.n_slots
    M = lpns.shape[0]

    lp_safe = jnp.maximum(lpns, 0)
    old_slot = s.l2p[lp_safe]
    valid = (lpns >= 0) & (old_slot >= 0)
    old_slot = jnp.where(valid, old_slot, 0)
    src_blk = old_slot // spb
    src_mode = s.block_mode[src_blk]
    # don't "migrate" pages already in the target mode
    valid &= src_mode != tgt_mode
    n_valid = valid.sum()

    # -- read cost of sources (each page is re-read to migrate) --
    age_h = cfg.device_age_h + (s.clock_ms - s.page_write_ms[old_slot]) / 3.6e6
    retries = retry.page_retries(src_mode, s.block_pe[src_blk], age_h, s.block_reads[src_blk], old_slot)
    lat_us = retry.read_latency_us(src_mode, retries)
    if faults is not None:
        mrr = faults.max_read_retries
        rated = modes.PE_LIMIT[src_mode]
        pe = s.block_pe[src_blk]
        over = valid & (mrr >= 0) & (retries > mrr)
        uncorr = over | (valid & flt.read_fails(faults, old_slot, pe, rated))
        rec_us = flt.recovery_us(faults, src_mode, cfg)
        lat_us = retry.read_latency_us(
            src_mode, jnp.where(over, jnp.maximum(mrr, 0), retries)
        ) + jnp.where(uncorr, rec_us, 0.0)
        s = _book_rebuilds(s, faults, uncorr, old_slot, pe, rated, cfg)
    rd_ms = jnp.where(valid, lat_us, 0.0) / 1000.0
    die_rd = jax.ops.segment_sum(rd_ms, cfg.die_of_block(src_blk),
                                 num_segments=cfg.n_dies)
    s = s._replace(die_busy_ms=s.die_busy_ms + die_rd)

    # -- invalidate old slots --
    drop_slot = jnp.where(valid, old_slot, S)
    p2l = s.p2l.at[drop_slot].set(-1, mode="drop")
    bv = s.block_valid - jax.ops.segment_sum(valid.astype(jnp.int32), src_blk, num_segments=s.block_valid.shape[0])
    s = s._replace(p2l=p2l, block_valid=bv)

    s = _place_pages(s, lpns, valid, tgt_mode, cfg, _dest_unroll(cfg, M))

    conv = jax.ops.segment_sum(valid.astype(jnp.float32), src_mode, num_segments=3)
    s = s._replace(
        n_migrated_pages=s.n_migrated_pages + n_valid,
        n_conversions=s.n_conversions.at[:, tgt_mode].add(conv),
    )
    if obs.full(cfg):
        # one event per source mode with pages moved this call: block -1
        # (page-granular — pages come from many blocks), trigger = the
        # policy's per-read conversion pipeline, conversion weight = pages
        retry_sum = jax.ops.segment_sum(
            jnp.where(valid, retries.astype(jnp.float32), 0.0), src_mode,
            num_segments=modes.N_MODES,
        )
        s = obs.record_events(
            s, cfg,
            mask=conv > 0,
            block=jnp.full((modes.N_MODES,), -1, jnp.int32),
            from_mode=jnp.arange(modes.N_MODES, dtype=jnp.int32),
            to_mode=jnp.full((modes.N_MODES,), tgt_mode, jnp.int32),
            reason=obs.REASON_CONV_PAGE,
            retry_est=retry_sum / jnp.maximum(conv, 1.0),
            pages=conv,
        )
    return s


def maybe_migrate_pages(s: st.SSDState, lpns, tgt_mode, cfg: geometry.SimConfig,
                        faults: flt.FaultParams | None = None):
    any_valid = (lpns >= 0).any()
    ok = any_valid & (free_block_count(s) >= _dest_unroll(cfg, lpns.shape[0]) + 2)
    return lax.cond(
        ok,
        lambda s_: migrate_pages(s_, lpns, tgt_mode, cfg, faults),
        lambda s_: s_,
        s,
    )


def _demote_dest_unroll(cfg: geometry.SimConfig, tgt_mode: int, n_victims: int) -> int:
    """Destination blocks needed by one fused demotion pass into ``tgt_mode``:
    up to ``n_victims`` source blocks one density level below the target,
    plus one partially-filled open block."""
    ppb = geometry.pages_per_block_host(cfg)
    src_pages = n_victims * int(ppb[tgt_mode - 1])
    return -(-src_pages // int(ppb[tgt_mode])) + 1


def relocate_group(s: st.SSDState, victims, grp, tgt_mode,
                   cfg: geometry.SimConfig, n_dest: int,
                   reason: int = obs.REASON_CONV_BLOCK,
                   faults: flt.FaultParams | None = None):
    """The fused relocation kernel (DESIGN.md §2A): migrate every
    ``grp``-masked victim block into ``tgt_mode`` in one placement pass,
    then erase all victims in one vectorized :func:`_erase_many`.

    GC relocation (tgt == victim mode), reclaim demotion (one call per
    demotion target) and block conversion (:func:`migrate_block`, K=1) are
    all this kernel with different victim sets; ``n_dest`` is the caller's
    static bound on destination blocks one pass can open. ``reason`` tags
    the per-victim observability events (DESIGN.md §7.4) with the trigger
    that fired the pass; the scalar reference paths do not record events,
    so the fused-vs-reference bit-identity tests run at ``obs_level="off"``.

    With ``faults`` active, migration reads whose Eq.-3 retry count exceeds
    the retry budget are uncorrectable: they burn the budget, pay the ECC
    recovery penalty and count into ``n_uncorrectable`` (the relocated copy
    is the soft-decoded data — migration itself still succeeds), and the
    victim erases can retire blocks (see :func:`_erase_many`). Migration
    programs are modeled as verified-good: re-placing a failed migration
    program would recurse into placement, and the recovery path it would
    exercise is already covered by the user write path's re-placement.
    """
    spb = cfg.slots_per_block

    vb = jnp.maximum(victims, 0)
    slots = vb[:, None] * spb + jnp.arange(spb, dtype=jnp.int32)[None, :]  # (K, spb)
    lpns = jnp.where(grp[:, None], s.p2l[slots], -1)
    valid = lpns >= 0
    src_mode = s.block_mode[vb]  # (K,)

    # -- read cost of all victim pages, one vectorized Eq.-3 pass --
    age_h = cfg.device_age_h + (s.clock_ms - s.page_write_ms[slots]) / 3.6e6
    retries = retry.page_retries(
        src_mode[:, None], s.block_pe[vb][:, None], age_h, s.block_reads[vb][:, None], slots
    )
    lat_us = retry.read_latency_us(src_mode[:, None], retries)
    if faults is not None:
        mrr = faults.max_read_retries
        rated = modes.PE_LIMIT[src_mode][:, None]
        pe = s.block_pe[vb][:, None]
        over = valid & (mrr >= 0) & (retries > mrr)
        uncorr = over | (valid & flt.read_fails(faults, slots, pe, rated))
        rec_us = flt.recovery_us(faults, src_mode[:, None], cfg)
        lat_us = retry.read_latency_us(
            src_mode[:, None], jnp.where(over, jnp.maximum(mrr, 0), retries)
        ) + jnp.where(uncorr, rec_us, 0.0)
        s = _book_rebuilds(s, faults, uncorr, slots, pe, rated, cfg)
    rd_ms = jnp.where(valid, lat_us, 0.0).sum(1) / 1000.0
    rd_w = jnp.where(grp, rd_ms, 0.0)
    if cfg.chan_model == "lattice" and cfg.planes_per_lun > 1:
        # multi-plane relocation reads on one die overlap (optimistic
        # cache-read model): co-scheduled plane victims pay the max of the
        # per-plane read times, matching the erase/program overlap charges
        per_plane = jax.ops.segment_sum(
            rd_w, cfg.plane_slot_of_block(vb),
            num_segments=cfg.n_dies * cfg.planes_per_die,
        )
        die_rd = per_plane.reshape(cfg.n_dies, cfg.planes_per_die).max(1)
    else:
        die_rd = jax.ops.segment_sum(rd_w, cfg.die_of_block(vb),
                                     num_segments=cfg.n_dies)
    s = s._replace(die_busy_ms=s.die_busy_ms + die_rd)

    s = _place_pages(s, lpns.reshape(-1), valid.reshape(-1), tgt_mode, cfg, n_dest)

    conv_src = jnp.where(grp, src_mode, modes.N_MODES)  # N_MODES = dropped
    s = s._replace(
        n_migrated_pages=s.n_migrated_pages + valid.sum(),
        n_conversions=s.n_conversions.at[conv_src, tgt_mode].add(1.0, mode="drop"),
    )
    if obs.full(cfg):
        pages = valid.sum(1).astype(jnp.float32)
        retry_mean = jnp.where(valid, retries.astype(jnp.float32), 0.0).sum(
            1
        ) / jnp.maximum(pages, 1.0)
        s = obs.record_events(
            s, cfg,
            mask=grp,
            block=vb,
            from_mode=src_mode,
            to_mode=jnp.broadcast_to(jnp.asarray(tgt_mode, jnp.int32),
                                     vb.shape),
            reason=reason,
            retry_est=retry_mean,
            pages=pages,
        )
    return _erase_many(s, victims, grp, cfg, faults=faults)


def reclaim_victims(s: st.SSDState, victims, v_ok, v_tgt, cfg: geometry.SimConfig,
                    faults: flt.FaultParams | None = None):
    """Fused reclaim demotion (paper §IV-E): the top-k victims selected by
    ``reclaim.select_demotion_victims`` are migrated in at most two masked
    passes (one per demotion target, SLC->TLC and TLC->QLC) instead of K
    sequential block migrations. Each pass is cond-gated on having victims
    and enough free destination blocks."""
    K = victims.shape[0]
    for tgt in (modes.TLC, modes.QLC):
        grp = v_ok & (v_tgt == tgt) & (s.block_state[jnp.maximum(victims, 0)] == st.FULL)
        ok = grp.any() & (free_block_count(s) >= _demote_dest_unroll(cfg, tgt, K) + 2)
        s = lax.cond(
            ok,
            lambda s_, grp=grp, tgt=tgt: relocate_group(
                s_, victims, grp, tgt, cfg, _demote_dest_unroll(cfg, tgt, K),
                reason=obs.REASON_RECLAIM, faults=faults,
            ),
            lambda s_: s_,
            s,
        )
    return s


def _gc_dest_need(cfg: geometry.SimConfig, k: int) -> int:
    """Free-pool guard headroom for a fused GC pass of up to ``k`` victims.

    One same-mode victim needs at most MAX_DEST destinations (the scalar
    reference's guard, kept so ``gc_victims_per_pass=1`` is bit-identical to
    it); every further victim fills at most one more fresh block.
    """
    return MAX_DEST + (k - 1)


def select_gc_victims(s: st.SSDState, cfg: geometry.SimConfig, k: int,
                      knobs=None):
    """Top-k GC victim selection via the unified scorer
    (``reclaim.score_victims``): among reclaimable FULL blocks — at least
    one invalid page at their current mode — the ``k`` best under
    ``cfg.gc_objective``, ties to the lowest block id. The default
    ``"min_valid"`` objective (fewest valid pages first) equals ``k``
    sequential greedy argmin picks because relocation never creates a new
    reclaimable block (placed blocks fill completely valid). A traced
    ``knobs.gc_objective`` code overrides the static objective per run."""
    code = None if knobs is None else getattr(knobs, "gc_objective", None)
    victims, ok, _ = reclaim.score_victims(s, cfg, cfg.gc_objective, k=k,
                                           objective_code=code)
    return victims, ok


def gc_step(s: st.SSDState, cfg: geometry.SimConfig,
            faults: flt.FaultParams | None = None, knobs=None):
    """Fused greedy GC, cond-gated on the free-pool watermark: with a
    healthy pool the victim scan is skipped entirely, so GC can never fire
    above ``cfg.gc_free_threshold``. Under pressure one firing relocates up
    to ``cfg.gc_victims_per_pass`` victims through :func:`relocate_group`,
    amortizing the full-device top-k, the placement unroll and the per-chunk
    dispatch over k blocks."""
    need = free_block_count(s) < cfg.gc_free_threshold
    return lax.cond(need, lambda s_: _gc_pass(s_, cfg, faults, knobs),
                    lambda s_: s_, s)


def _gc_pass(s: st.SSDState, cfg: geometry.SimConfig,
             faults: flt.FaultParams | None = None, knobs=None):
    """One fused GC firing: top-k min-valid victims relocated in a single
    masked :func:`relocate_group` pass over the batch's dominant source
    mode (GC keeps each block's mode), cond-gated on having victims and
    free headroom.

    The batch is deficit-aware (per-victim projected net reclaim
    ``1 - valid/pages`` from the selection-time counts, prefix-summed
    best-first): victims are *forced* while the projection is still needed
    to lift the pool back to ``gc_free_threshold``, and taken
    *opportunistically* beyond that — up to ``k - 1`` blocks of hysteresis
    headroom — only when they offer at least half the batch's best
    projected harvest (i.e. comparably cheap to the victim GC would have
    picked anyway). One firing then builds enough slack that the following chunks
    skip GC entirely, amortizing the full-device top-k, the placement
    unroll and the cond/dispatch overhead over the batch, while valid-heavy
    victims deep in the ranking are never relocated early (they decay to
    cheap victims by the time they are actually needed — relocating them
    now would multiply write amplification, and with a thin invalid
    inventory the pass degrades gracefully to the reference's
    one-victim-per-firing behavior). With ``k = 1`` the mask is always
    true, keeping the pass bit-identical to ``gc_pass_reference``. ``k``
    victims each with >= 1 invalid page place into at most ``k`` fresh
    blocks plus the open migration block, so the placement unroll is
    ``k + 1``.

    Under the ``"lifespan"`` objective the lanes arrive ordered by *score*
    (wear-discounted), not by projected harvest, so lane 0's ``net`` is the
    preferred victim's harvest rather than the maximum — the deficit
    batching then forces however many score-ordered victims the projection
    needs, which is exactly the wear-levelled trade the objective asks
    for."""
    k = min(max(int(cfg.gc_victims_per_pass), 1), cfg.n_blocks)
    victims, ok = select_gc_victims(s, cfg, k, knobs)
    vb = jnp.maximum(victims, 0)
    ppb = geometry.pages_per_block(cfg)
    vmode = s.block_mode[vb]
    net = jnp.where(ok, 1.0 - s.block_valid[vb] / ppb[vmode].astype(jnp.float32), 0.0)
    cum_before = jnp.cumsum(net) - net  # projected reclaim of better victims
    deficit = (cfg.gc_free_threshold - free_block_count(s)).astype(jnp.float32)
    forced = cum_before < deficit
    # opportunistic batching: only victims offering at least half the best
    # victim's harvest ride along (victims are ordered best-first, so lane 0
    # holds the batch's best projected net reclaim)
    cheap = net >= 0.5 * net[0]
    ok &= forced | (cheap & (cum_before < deficit + (k - 1)))
    # one relocation pass per firing, on the dominant source mode's victims
    # (a GC batch is virtually always single-mode — user data lives in QLC;
    # minority-mode victims simply wait for a later firing)
    cnt = jax.ops.segment_sum(ok.astype(jnp.int32), vmode, num_segments=modes.N_MODES)
    tgt = jnp.argmax(cnt).astype(jnp.int32)
    grp = ok & (vmode == tgt) & (s.block_state[vb] == st.FULL)
    go = grp.any() & (free_block_count(s) >= _gc_dest_need(cfg, k) + 2)
    return lax.cond(
        go,
        lambda s_: relocate_group(s_, victims, grp, tgt, cfg, k + 1,
                                  reason=obs.REASON_GC, faults=faults),
        lambda s_: s_,
        s,
    )


def gc_step_reference(s: st.SSDState, cfg: geometry.SimConfig):
    """Watermark-gated wrapper over :func:`gc_pass_reference` (mirrors
    :func:`gc_step`); reference-only, for the bit-identity tests."""
    need = free_block_count(s) < cfg.gc_free_threshold
    return lax.cond(need, lambda s_: gc_pass_reference(s_, cfg), lambda s_: s_, s)


def gc_pass_reference(s: st.SSDState, cfg: geometry.SimConfig):
    """The original scalar single-victim GC pass — argmin victim scan plus
    one sequential block migration — retained purely as the behavioral
    reference: the fused :func:`_gc_pass` with ``gc_victims_per_pass=1``
    must be bit-identical to it (asserted in tier-1)."""
    ppb = geometry.pages_per_block(cfg)
    full = s.block_state == st.FULL
    reclaimable = full & (s.block_valid < ppb[s.block_mode])
    score = jnp.where(reclaimable, s.block_valid, jnp.iinfo(jnp.int32).max)
    victim = jnp.argmin(score).astype(jnp.int32)
    src = jnp.where(reclaimable[victim], victim, -1)
    tgt_mode = s.block_mode[victim]
    ok = (src >= 0) & (free_block_count(s) >= MAX_DEST + 2)
    ok &= s.block_state[jnp.maximum(src, 0)] == st.FULL
    return lax.cond(
        ok,
        lambda s_: _migrate_block_reference(s_, jnp.maximum(src, 0), tgt_mode, cfg),
        lambda s_: s_,
        s,
    )
