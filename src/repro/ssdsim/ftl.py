"""FTL mechanics: block allocation, block-granularity migration/conversion
(paper Fig. 8-10), greedy GC, fused reclaim demotion. Everything is jit-safe
with static shapes; per-block operations work on the block's fixed
slots_per_block window.

Scatter discipline: masked-out lanes are redirected to an out-of-range index
and dropped (``mode='drop'``) — never write a dummy in-range index, because
duplicate-index ``set`` conflicts are unordered in XLA.

Free-pool bookkeeping (DESIGN.md §2A): ``SSDState.free_count`` is the exact
number of FREE blocks, incremented by ``_erase`` and decremented at the two
places a FREE block is opened (``_place_pages`` and the engine write path).
``SSDState.free_hint`` holds one candidate free block per LUN, refreshed on
erase; ``alloc_free_block`` trusts a hint only after re-checking
``block_state`` and falls back to the O(n_blocks) scan when no hint is live.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import modes, retry
from repro.ssdsim import geometry, state as st

# Max destination blocks one conversion can need: one partially-filled open
# migration block plus ceil(1024/256) = 4 fresh SLC blocks.
MAX_DEST = 5


def _alloc_scan(s: st.SSDState, prefer_lun=None, cfg: geometry.SimConfig | None = None):
    """Full block_state scan (slow path): free block, prefer matching LUN."""
    free = s.block_state == st.FREE
    if prefer_lun is not None:
        blk = jnp.arange(s.block_mode.shape[0], dtype=jnp.int32)
        lun_match = (blk % cfg.n_luns) == prefer_lun
        score = free.astype(jnp.int32) * 2 + (free & lun_match).astype(jnp.int32)
    else:
        score = free.astype(jnp.int32)
    idx = jnp.argmax(score).astype(jnp.int32)
    return jnp.where(score[idx] > 0, idx, -1)


def alloc_free_block(s: st.SSDState, prefer_lun=None, cfg: geometry.SimConfig | None = None):
    """Index of a free block (prefer matching LUN), or -1 if none.

    O(1) fast path through the per-LUN free hints; the hint is validated
    against ``block_state`` (hints go stale when consumed) and the full scan
    runs only when it is dead. With ``prefer_lun`` only that LUN's hint is
    trusted, so LUN affinity is never worse than the scan's.
    """
    hints = s.free_hint
    live = (hints >= 0) & (s.block_state[jnp.maximum(hints, 0)] == st.FREE)
    if prefer_lun is not None:
        h = hints[prefer_lun]
        hit = live[prefer_lun]
    else:
        j = jnp.argmax(live)
        h = hints[j]
        hit = live[j]
    return lax.cond(
        hit,
        lambda: h.astype(jnp.int32),
        lambda: _alloc_scan(s, prefer_lun, cfg),
    )


def free_block_count(s: st.SSDState):
    """Exact FREE-block count, O(1) via the incremental bookkeeping."""
    return s.free_count


def _erase(s: st.SSDState, blk, cfg: geometry.SimConfig):
    """Erase ``blk``: invalidate slots, bump P/E, return to free pool."""
    spb = cfg.slots_per_block
    mode = s.block_mode[blk]
    p2l = lax.dynamic_update_slice(s.p2l, jnp.full((spb,), -1, jnp.int32), (blk * spb,))
    lun = blk % cfg.n_luns
    erase_ms = modes.ERASE_LATENCY_US[mode] / 1000.0
    return s._replace(
        p2l=p2l,
        block_pe=s.block_pe.at[blk].add(1),
        block_reads=s.block_reads.at[blk].set(0),
        block_state=s.block_state.at[blk].set(st.FREE),
        block_next=s.block_next.at[blk].set(0),
        block_valid=s.block_valid.at[blk].set(0),
        block_cold_age=s.block_cold_age.at[blk].set(0),
        free_count=s.free_count + 1,
        free_hint=s.free_hint.at[lun].set(blk.astype(jnp.int32)),
        lun_busy_ms=s.lun_busy_ms.at[lun].add(erase_ms),
        n_erases=s.n_erases + 1.0,
    )


def _place_pages(s: st.SSDState, lpns, valid, tgt_mode, cfg: geometry.SimConfig,
                 n_dest: int):
    """Append the ``valid``-masked ``lpns`` into open migration block(s) of
    ``tgt_mode``, opening up to ``n_dest`` fresh blocks from the free pool.

    Shared placement core of page migration, block migration and the fused
    reclaim pass — besides the engine write path this is the only place FREE
    blocks are consumed, so the free-pool bookkeeping lives here once.
    Callers invalidate (or erase) the source slots themselves.
    """
    spb = cfg.slots_per_block
    ppb = geometry.pages_per_block(cfg)
    S, L = cfg.n_slots, cfg.n_logical

    lp_safe = jnp.maximum(lpns, 0)
    pos = jnp.cumsum(valid.astype(jnp.int32)) - 1  # rank of each valid page
    n_valid = valid.sum()
    consumed = jnp.int32(0)
    for _ in range(n_dest):
        cur = s.open_mig[tgt_mode]
        fresh = cur < 0
        a = alloc_free_block(s)
        d = jnp.where(fresh, a, cur)
        dd = jnp.maximum(d, 0)  # safe index; all writes masked when d < 0
        usable = jnp.where(d >= 0, ppb[tgt_mode] - s.block_next[dd], 0)
        take = jnp.clip(n_valid - consumed, 0, usable)
        opened = (take > 0) & (d >= 0)
        sel = valid & (pos >= consumed) & (pos < consumed + take) & opened

        dest_off = s.block_next[dd] + (pos - consumed)
        dest_slot = jnp.where(sel, dd * spb + dest_off, S)  # S = dropped
        lp_idx = jnp.where(sel, lpns, L)  # L = dropped

        s = s._replace(
            block_mode=s.block_mode.at[dd].set(
                jnp.where(opened, tgt_mode, s.block_mode[dd])
            ),
            block_state=s.block_state.at[dd].set(
                jnp.where(opened, st.OPEN, s.block_state[dd])
            ),
            free_count=s.free_count - jnp.where(opened & fresh, 1, 0),
        )
        l2p = s.l2p.at[lp_idx].set(dest_slot, mode="drop")
        p2l = s.p2l.at[dest_slot].set(lp_safe, mode="drop")
        pwt = s.page_write_ms.at[dest_slot].set(s.clock_ms, mode="drop")

        write_ms = take * modes.WRITE_LATENCY_US[tgt_mode] / 1000.0
        new_next = s.block_next[dd] + take
        is_full = new_next >= ppb[tgt_mode]
        s = s._replace(
            l2p=l2p,
            p2l=p2l,
            page_write_ms=pwt,
            block_next=s.block_next.at[dd].add(jnp.where(opened, take, 0)),
            block_valid=s.block_valid.at[dd].add(jnp.where(opened, take, 0)),
            block_state=s.block_state.at[dd].set(
                jnp.where(opened & is_full, st.FULL, s.block_state.at[dd].get())
            ),
            open_mig=s.open_mig.at[tgt_mode].set(
                jnp.where(opened, jnp.where(is_full, -1, d), s.open_mig[tgt_mode])
            ),
            lun_busy_ms=s.lun_busy_ms.at[dd % cfg.n_luns].add(write_ms),
        )
        consumed = consumed + take
    return s


def migrate_block(s: st.SSDState, src, tgt_mode, cfg: geometry.SimConfig):
    """Move all valid pages of ``src`` into open migration block(s) of
    ``tgt_mode``, then erase ``src``. This is both mode conversion
    (tgt != src mode) and GC relocation (tgt == src mode).

    Latency accounting: each valid page costs one source-mode read (with its
    Eq.-3 retry count) plus one target-mode program; the erase costs the
    source-mode erase latency. Requires up to MAX_DEST destination blocks;
    the caller guards on free_block_count.
    """
    spb = cfg.slots_per_block

    src_mode = s.block_mode[src]
    slots = src * spb + jnp.arange(spb, dtype=jnp.int32)
    lpns = lax.dynamic_slice(s.p2l, (src * spb,), (spb,))
    valid = lpns >= 0
    n_valid = valid.sum()

    # -- read cost of the source pages (Eq. 1 -> Eq. 3 per page) --
    age_h = (
        cfg.device_age_h
        + (s.clock_ms - lax.dynamic_slice(s.page_write_ms, (src * spb,), (spb,))) / 3.6e6
    )
    retries = retry.page_retries(src_mode, s.block_pe[src], age_h, s.block_reads[src], slots)
    read_ms = jnp.where(valid, retry.read_latency_us(src_mode, retries), 0.0).sum() / 1000.0
    src_lun = src % cfg.n_luns
    s = s._replace(lun_busy_ms=s.lun_busy_ms.at[src_lun].add(read_ms))

    # source slots die with the erase below; no explicit invalidation needed
    s = _place_pages(s, lpns, valid, tgt_mode, cfg, MAX_DEST)

    s = s._replace(
        n_migrated_pages=s.n_migrated_pages + n_valid,
        n_conversions=s.n_conversions.at[src_mode, tgt_mode].add(1.0),
    )
    return _erase(s, src, cfg)


def _dest_unroll(cfg: geometry.SimConfig, n_pages: int) -> int:
    """Destination blocks needed to place ``n_pages`` into the smallest-
    capacity mode (SLC), plus one partially-filled open block."""
    slc_ppb = max(cfg.slots_per_block // 4, 1)
    return -(-n_pages // slc_ppb) + 1


def migrate_pages(s: st.SSDState, lpns, tgt_mode, cfg: geometry.SimConfig):
    """Page-granular conversion migration (paper Fig. 9/10): move the given
    logical pages into open block(s) programmed in ``tgt_mode``, invalidating
    their old slots. The destination block is the unit of mode uniformity
    ("flash type alignment"); source blocks are compacted later by GC.

    ``lpns``: (M,) int32, -1-padded. M is static (cfg.migrate_pages_per_chunk).
    """
    spb = cfg.slots_per_block
    S = cfg.n_slots
    M = lpns.shape[0]

    lp_safe = jnp.maximum(lpns, 0)
    old_slot = s.l2p[lp_safe]
    valid = (lpns >= 0) & (old_slot >= 0)
    old_slot = jnp.where(valid, old_slot, 0)
    src_blk = old_slot // spb
    src_mode = s.block_mode[src_blk]
    # don't "migrate" pages already in the target mode
    valid &= src_mode != tgt_mode
    n_valid = valid.sum()

    # -- read cost of sources (each page is re-read to migrate) --
    age_h = cfg.device_age_h + (s.clock_ms - s.page_write_ms[old_slot]) / 3.6e6
    retries = retry.page_retries(src_mode, s.block_pe[src_blk], age_h, s.block_reads[src_blk], old_slot)
    rd_ms = jnp.where(valid, retry.read_latency_us(src_mode, retries), 0.0) / 1000.0
    lun_rd = jax.ops.segment_sum(rd_ms, src_blk % cfg.n_luns, num_segments=cfg.n_luns)
    s = s._replace(lun_busy_ms=s.lun_busy_ms + lun_rd)

    # -- invalidate old slots --
    drop_slot = jnp.where(valid, old_slot, S)
    p2l = s.p2l.at[drop_slot].set(-1, mode="drop")
    bv = s.block_valid - jax.ops.segment_sum(valid.astype(jnp.int32), src_blk, num_segments=s.block_valid.shape[0])
    s = s._replace(p2l=p2l, block_valid=bv)

    s = _place_pages(s, lpns, valid, tgt_mode, cfg, _dest_unroll(cfg, M))

    conv = jax.ops.segment_sum(valid.astype(jnp.float32), src_mode, num_segments=3)
    return s._replace(
        n_migrated_pages=s.n_migrated_pages + n_valid,
        n_conversions=s.n_conversions.at[:, tgt_mode].add(conv),
    )


def maybe_migrate_pages(s: st.SSDState, lpns, tgt_mode, cfg: geometry.SimConfig):
    any_valid = (lpns >= 0).any()
    ok = any_valid & (free_block_count(s) >= _dest_unroll(cfg, lpns.shape[0]) + 2)
    return lax.cond(
        ok,
        lambda s_: migrate_pages(s_, lpns, tgt_mode, cfg),
        lambda s_: s_,
        s,
    )


def maybe_migrate_block(s: st.SSDState, src, tgt_mode, cfg: geometry.SimConfig):
    """cond-wrapped migration: no-op when src < 0, the free pool cannot
    cover MAX_DEST destinations, or the block is not FULL (converting a
    block still being programmed would race the write path)."""
    ok = (src >= 0) & (free_block_count(s) >= MAX_DEST + 2)
    ok &= s.block_state[jnp.maximum(src, 0)] == st.FULL
    return lax.cond(
        ok,
        lambda s_: migrate_block(s_, jnp.maximum(src, 0), tgt_mode, cfg),
        lambda s_: s_,
        s,
    )


def _demote_dest_unroll(cfg: geometry.SimConfig, tgt_mode: int, n_victims: int) -> int:
    """Destination blocks needed by one fused demotion pass into ``tgt_mode``:
    up to ``n_victims`` source blocks one density level below the target,
    plus one partially-filled open block."""
    ppb = geometry.pages_per_block_host(cfg)
    src_pages = n_victims * int(ppb[tgt_mode - 1])
    return -(-src_pages // int(ppb[tgt_mode])) + 1


def _demote_group(s: st.SSDState, victims, grp, tgt_mode: int,
                  cfg: geometry.SimConfig):
    """Migrate every ``grp``-masked victim block into ``tgt_mode`` in one
    placement pass, then erase the victims. The fused replacement for K
    sequential ``migrate_block`` calls (DESIGN.md §2A)."""
    K = victims.shape[0]
    spb = cfg.slots_per_block

    vb = jnp.maximum(victims, 0)
    slots = vb[:, None] * spb + jnp.arange(spb, dtype=jnp.int32)[None, :]  # (K, spb)
    lpns = jnp.where(grp[:, None], s.p2l[slots], -1)
    valid = lpns >= 0
    src_mode = s.block_mode[vb]  # (K,)

    # -- read cost of all victim pages, one vectorized Eq.-3 pass --
    age_h = cfg.device_age_h + (s.clock_ms - s.page_write_ms[slots]) / 3.6e6
    retries = retry.page_retries(
        src_mode[:, None], s.block_pe[vb][:, None], age_h, s.block_reads[vb][:, None], slots
    )
    rd_ms = jnp.where(valid, retry.read_latency_us(src_mode[:, None], retries), 0.0).sum(1) / 1000.0
    lun_rd = jax.ops.segment_sum(
        jnp.where(grp, rd_ms, 0.0), vb % cfg.n_luns, num_segments=cfg.n_luns
    )
    s = s._replace(lun_busy_ms=s.lun_busy_ms + lun_rd)

    s = _place_pages(
        s, lpns.reshape(-1), valid.reshape(-1), tgt_mode, cfg,
        _demote_dest_unroll(cfg, tgt_mode, K),
    )

    conv_src = jnp.where(grp, src_mode, modes.N_MODES)  # N_MODES = dropped
    s = s._replace(
        n_migrated_pages=s.n_migrated_pages + valid.sum(),
        n_conversions=s.n_conversions.at[conv_src, tgt_mode].add(1.0, mode="drop"),
    )
    for i in range(K):
        s = lax.cond(
            grp[i],
            lambda s_, i=i: _erase(s_, vb[i], cfg),
            lambda s_: s_,
            s,
        )
    return s


def reclaim_victims(s: st.SSDState, victims, v_ok, v_tgt, cfg: geometry.SimConfig):
    """Fused reclaim demotion (paper §IV-E): the top-k victims selected by
    ``reclaim.select_demotion_victims`` are migrated in at most two masked
    passes (one per demotion target, SLC->TLC and TLC->QLC) instead of K
    sequential block migrations. Each pass is cond-gated on having victims
    and enough free destination blocks."""
    K = victims.shape[0]
    for tgt in (modes.TLC, modes.QLC):
        grp = v_ok & (v_tgt == tgt) & (s.block_state[jnp.maximum(victims, 0)] == st.FULL)
        ok = grp.any() & (free_block_count(s) >= _demote_dest_unroll(cfg, tgt, K) + 2)
        s = lax.cond(
            ok,
            lambda s_, grp=grp, tgt=tgt: _demote_group(s_, victims, grp, tgt, cfg),
            lambda s_: s_,
            s,
        )
    return s


def gc_step(s: st.SSDState, cfg: geometry.SimConfig):
    """Greedy GC, cond-gated on the free-pool watermark: with a healthy pool
    the victim scan is skipped entirely, so GC can never fire above
    ``cfg.gc_free_threshold``. (The idle branch is an explicit no-op now —
    it previously still selected a victim and read its mode as the
    relocation target.)"""
    need = free_block_count(s) < cfg.gc_free_threshold
    return lax.cond(need, lambda s_: _gc_pass(s_, cfg), lambda s_: s_, s)


def _gc_pass(s: st.SSDState, cfg: geometry.SimConfig):
    """Relocate the FULL block with the fewest valid pages (and at least one
    invalid page); no-op via maybe_migrate_block when nothing is reclaimable."""
    ppb = geometry.pages_per_block(cfg)
    full = s.block_state == st.FULL
    reclaimable = full & (s.block_valid < ppb[s.block_mode])
    score = jnp.where(reclaimable, s.block_valid, jnp.iinfo(jnp.int32).max)
    victim = jnp.argmin(score).astype(jnp.int32)
    src = jnp.where(reclaimable[victim], victim, -1)
    return maybe_migrate_block(s, src, s.block_mode[victim], cfg)
