"""Emulated-SSD geometry and simulation configuration (paper Table III).

Default geometry: 2 channels x 2 dies/channel x 1 plane x 256 blocks/plane,
16 KiB pages, 256/768/1024 pages per SLC/TLC/QLC block -> 16 GiB raw QLC
capacity; the paper's working set is 8 GiB (524,288 logical pages).

Resource lattice (DESIGN.md §2C): timing resources form a
``(channel, die, plane)`` hierarchy. A *die* (what ONFI calls a LUN) owns
sense/program/erase occupancy; the *channel* bus it hangs off serializes
page transfers across its dies; *planes* within a die can co-schedule
program/erase and overlap. Block ids interleave die-first —
``die = block % n_dies``, ``plane = (block // n_dies) % planes_per_lun`` —
so consecutive blocks stripe across dies exactly like the historical
``blk % n_luns`` LUN striping (``n_dies == n_luns``; the block -> die map is
unchanged, which is what keeps the legacy timing model reachable
bit-for-bit).

``chan_model`` selects the timing model: ``"legacy"`` (default) is the
one-clock-per-LUN scheduler — transfer never queues — and ``"lattice"``
adds per-channel transfer clocks and multi-plane overlap.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field, replace

from repro.core import hotness, modes

CHAN_MODELS = ("legacy", "lattice")

# Statically configurable GC victim objectives. Mirrors
# reclaim.GC_OBJECTIVES (kept as a literal here so the config layer stays
# importable without jax; cross-checked by tests/test_endurance.py).
GC_OBJECTIVES = ("min_valid", "lifespan")

# Free-block allocation policies: "lowest_id" is the historical
# first-free-id scan (pinned bit-identical); "youngest" steers allocation
# toward the lowest-P/E free block (wear-levelled allocation — the other
# half of wear levelling next to the lifespan GC victim scorer).
ALLOC_POLICIES = ("lowest_id", "youngest")

_ALIAS_WARNED: set[str] = set()

BASELINE = 0  # multi-read-retry QLC, no mode awareness
HOTNESS = 1  # temperature-only 3-mode conversion (paper's comparison)
RARO = 2  # this paper
POLICY_NAMES = ("baseline", "hotness", "raro")


@dataclass(frozen=True)
class SimConfig:
    # --- Table III geometry ---
    n_channels: int = 2
    luns_per_channel: int = 2
    planes_per_lun: int = 1
    blocks_per_plane: int = 256
    page_kib: int = 16
    slots_per_block: int = 1024  # physical wordline slots == QLC page count

    # --- workload footprint ---
    n_logical: int = 524_288  # 8 GiB of 16 KiB pages

    # --- engine ---
    chunk: int = 1024  # requests per vectorized step (FTL background period)
    migrate_pages_per_chunk: int = 128  # page-granular conversion budget/mode
    max_conversions_per_chunk: int = 4  # block-granular ops (GC/reclaim)
    gc_free_threshold: int = 8  # min free blocks before GC kicks in
    gc_victims_per_pass: int = 4  # blocks relocated per fused GC firing
    device_age_h: float = 100.0  # retention baseline (pre-aged device)
    channel_mb_s: float = 800.0  # ONFI channel bandwidth for page transfer
    # timing model (DESIGN.md §2C): "legacy" = one opaque clock per LUN,
    # transfer appended to latency but never queued (the historical model);
    # "lattice" = two-resource (die, channel) tandem queue with per-channel
    # transfer clocks and multi-plane program/erase overlap
    chan_model: str = "legacy"

    # --- observability (DESIGN.md §7.4) ---
    # "off": no obs ops traced at all (zero-length accumulator leaves);
    # "counters": per-mode latency count histograms + windowed time series;
    # "full": + per-component latency decomposition + the event ring buffer.
    obs_level: str = "off"
    obs_event_capacity: int = 256  # ring slots (overwrite-oldest beyond)
    obs_windows: int = 64  # time-series windows (last absorbs overflow)
    obs_window_ms: float = 50.0  # simulated time per window

    # --- fault injection (DESIGN.md §2D) ---
    # max_read_retries < 0: every read eventually decodes (the optimistic
    # pre-fault model). >= 0: a read whose Eq.-3 retry count exceeds the
    # budget is uncorrectable — it burns the budget, pays read_recovery_us
    # of ECC soft-decode/recovery, and increments n_uncorrectable. Only
    # budgets below the mode's retry-table limit (modes.MAX_RETRIES) can
    # fire, since page_retries clips at the table.
    max_read_retries: int = -1
    read_recovery_us: float = 5000.0  # flat ECC soft-decode penalty
    prog_fail_rate: float = 0.0  # per page program (user write path)
    erase_fail_rate: float = 0.0  # per block erase -> bad-block retirement
    read_fail_rate: float = 0.0  # per page read -> probabilistic uncorrectable
    fault_seed: int = 0  # stream selector for the deterministic draws
    # wear curve: every fault rate scales by 1 + slope*(pe/rated)^power,
    # evaluated per operation from the failing block's P/E count. Slope 0.0
    # (default) is bit-identical to the flat-rate PR 7 model.
    fault_wear_slope: float = 0.0
    fault_wear_power: float = 4.0
    # uncorrectable-recovery model: False = flat read_recovery_us penalty;
    # True = die-parity stripe rebuild (peer senses + serialized channel
    # transfers, charged on the timing lattice) with a second-fault path
    # counting true data loss
    parity_rebuild: bool = False
    # over-provisioning spare pool: erase-fail retirements consume spares
    # before eating usable capacity; 0 remaining flips the engine into
    # read-only degraded mode (writes dropped + counted, mapping intact).
    # < 0 = unbounded pool (the PR 7 accounting, pinned bit-identical).
    spare_blocks: int = -1

    # --- GC victim objective (DESIGN.md §2E) ---
    # "min_valid": classic fewest-valid-pages-first (the pinned default);
    # "lifespan": score = α·invalid_ratio − β·migration_cost − γ·pe_norm,
    # trading a little immediate harvest for flatter wear. Also selectable
    # per-run as a traced RunKnobs sweep axis (RunKnobs.gc_objective).
    gc_objective: str = "min_valid"
    gc_alpha: float = 1.0
    gc_beta: float = 0.5
    gc_gamma: float = 0.3

    # --- free-block allocation policy (wear levelling) ---
    # "lowest_id": historical first-free-id scan (pinned bit-identical);
    # "youngest": lowest-P/E free block first (die affinity still wins,
    # ties break to the lowest id).
    alloc_policy: str = "lowest_id"

    # --- policy ---
    policy: int = RARO
    r1: int = 1
    r2_override: int = -1  # <0: use the paper's stage schedule (5/7/11)
    heat: hotness.HeatConfig = field(default_factory=hotness.HeatConfig)
    reclaim_enabled: bool = True

    # --- initial wear (paper evaluates young/middle/old devices) ---
    initial_pe: int = 166

    def __post_init__(self):
        if self.chan_model not in CHAN_MODELS:
            raise ValueError(
                f"chan_model must be one of {CHAN_MODELS}, "
                f"got {self.chan_model!r}"
            )
        if self.gc_objective not in GC_OBJECTIVES:
            raise ValueError(
                f"gc_objective must be one of {GC_OBJECTIVES}, "
                f"got {self.gc_objective!r}"
            )
        if self.alloc_policy not in ALLOC_POLICIES:
            raise ValueError(
                f"alloc_policy must be one of {ALLOC_POLICIES}, "
                f"got {self.alloc_policy!r}"
            )
        if self.fault_wear_power <= 0.0:
            raise ValueError(
                f"fault_wear_power must be > 0, got {self.fault_wear_power}"
            )

    @property
    def n_luns(self) -> int:
        return self.n_channels * self.luns_per_channel

    @property
    def n_dies(self) -> int:
        """Dies in the device — one die per historical LUN (``n_dies ==
        n_luns``; "LUN" is ONFI's name for a die, kept as the legacy
        alias)."""
        return self.n_channels * self.luns_per_channel

    @property
    def planes_per_die(self) -> int:
        return self.planes_per_lun

    @property
    def n_blocks(self) -> int:
        return self.n_luns * self.planes_per_lun * self.blocks_per_plane

    @property
    def n_slots(self) -> int:
        return self.n_blocks * self.slots_per_block

    @property
    def page_bytes(self) -> int:
        return self.page_kib * 1024

    @property
    def faults_enabled(self) -> bool:
        """Static trace-time gate: any fault class configured on the config
        itself. (The sweep runner can also activate faults per run through
        traced ``RunKnobs`` fields — see ``repro.core.faults.params_for``.)"""
        return (self.max_read_retries >= 0 or self.prog_fail_rate > 0.0
                or self.erase_fail_rate > 0.0 or self.read_fail_rate > 0.0)

    @property
    def transfer_us(self) -> float:
        """Channel transfer time of one page (16 KiB @ 800 MB/s ~= 20 us)."""
        return self.page_bytes / (self.channel_mb_s * 1e6) * 1e6

    @property
    def rebuild_xfer_chain(self) -> int:
        """Serialized peer transfers on a die-parity rebuild's critical path.

        A rebuild reads the victim page's ``n_dies - 1`` stripe peers; their
        senses overlap across dies but every peer page must cross a channel
        bus. With multiple channels the peers split evenly across buses
        (dies stripe across channels), so the busiest bus carries
        ``luns_per_channel`` transfers; on a single channel all peers
        serialize behind each other."""
        if self.n_channels > 1:
            return self.luns_per_channel
        return max(self.n_dies - 1, 0)

    # --- lattice indexing (works on python ints and traced arrays) ---

    def die_of_block(self, block):
        """Owning die of a block: blocks stripe die-first, so consecutive
        block ids land on consecutive dies (identical to the historical
        ``blk % n_luns`` LUN striping)."""
        return block % self.n_dies

    def plane_of_block(self, block):
        """Plane within its die: after the die stripe, blocks cycle through
        the die's planes."""
        return (block // self.n_dies) % self.planes_per_die

    def channel_of_die(self, die):
        """Channel bus a die hangs off (dies stripe across channels)."""
        return die % self.n_channels

    def plane_slot_of_block(self, block):
        """Flattened ``die * planes_per_die + plane`` index — the segment id
        for per-(die, plane) reductions (reshape to ``(n_dies, planes)``)."""
        return self.die_of_block(block) * self.planes_per_die + \
            self.plane_of_block(block)

    def lun_of_block(self, block):
        """Deprecated legacy alias — use :meth:`die_of_block` (the
        historical LUN of a block is its die). Warns once per process; no
        ``src/`` module may call it (grep-enforced by tests)."""
        if "lun_of_block" not in _ALIAS_WARNED:
            _ALIAS_WARNED.add("lun_of_block")
            warnings.warn("SimConfig.lun_of_block is deprecated; use die_of_block",
                          DeprecationWarning, stacklevel=2)
        return self.die_of_block(block)

    def channel_of_lun(self, lun):
        """Deprecated legacy alias — use :meth:`channel_of_die`. Warns once
        per process; no ``src/`` module may call it (grep-enforced)."""
        if "channel_of_lun" not in _ALIAS_WARNED:
            _ALIAS_WARNED.add("channel_of_lun")
            warnings.warn("SimConfig.channel_of_lun is deprecated; use channel_of_die",
                          DeprecationWarning, stacklevel=2)
        return self.channel_of_die(lun)

    def with_policy(self, policy: int) -> "SimConfig":
        return replace(self, policy=policy)


def tiny_config(**kw) -> SimConfig:
    """Small geometry for unit tests (fast on CPU)."""
    base = dict(
        n_channels=2,
        luns_per_channel=2,
        blocks_per_plane=16,
        slots_per_block=64,
        page_kib=16,
        n_logical=1536,
        chunk=128,
        migrate_pages_per_chunk=16,
        max_conversions_per_chunk=2,
        gc_free_threshold=2,
        gc_victims_per_pass=2,
    )
    base.update(kw)
    return SimConfig(**base)


# Pages per block if the block were opened in each mode, scaled to the
# configured slots_per_block (Table III ratios 256:768:1024).
def pages_per_block(cfg: SimConfig):
    import jax.numpy as jnp

    ratio = modes.PAGES_PER_BLOCK / modes.PAGES_PER_BLOCK[modes.QLC]
    return jnp.maximum((ratio * cfg.slots_per_block).astype(jnp.int32), 1)


def pages_per_block_host(cfg: SimConfig):
    """Host-side (numpy) twin of :func:`pages_per_block`, for computing
    static unroll bounds at trace time. Must round identically."""
    import numpy as np

    ppb = np.asarray(modes.PAGES_PER_BLOCK)
    ratio = ppb.astype(np.float32) / np.float32(ppb[modes.QLC])
    return np.maximum((ratio * cfg.slots_per_block).astype(np.int32), 1)
