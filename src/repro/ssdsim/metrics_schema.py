"""Single metrics-schema registry: name → (unit, description, scalar).

One source of truth for every key ``engine.summarize`` can emit.
``sweep._ROW_UNITS`` (the flattening of run results into harness-style
``(name, value, unit)`` rows) and the ``benchmarks/report.py`` renderers
both derive their units from here, so a new metric — e.g. the §2E
endurance rows — registers in exactly one place. A tier-1 test pins
``summarize`` output keys ⊆ this schema at every ``obs_level``.

``scalar=False`` marks nested-list metrics (per-mode / matrix shapes)
that cannot flatten into a single sweep row; :func:`row_units` excludes
them. Insertion order of the scalar entries is the row order of sweep
artifacts — append, don't reorder.
"""

from __future__ import annotations

from typing import NamedTuple


class Metric(NamedTuple):
    unit: str
    description: str
    scalar: bool = True


SCHEMA: dict[str, Metric] = {
    # ---- throughput / latency ----
    "iops": Metric("IOPS", "read throughput over the device makespan"),
    "mean_read_latency_us": Metric("us", "mean recorded user-read latency"),
    "read_lat_p50_us": Metric("us", "read latency 50th percentile"),
    "read_lat_p95_us": Metric("us", "read latency 95th percentile"),
    "read_lat_p99_us": Metric("us", "read latency 99th percentile"),
    "read_lat_p999_us": Metric("us", "read latency 99.9th percentile"),
    "write_lat_p50_us": Metric("us", "write latency 50th percentile"),
    "write_lat_p95_us": Metric("us", "write latency 95th percentile"),
    "write_lat_p99_us": Metric("us", "write latency 99th percentile"),
    "write_lat_p999_us": Metric("us", "write latency 99.9th percentile"),
    "read_queue_delay_us": Metric("us", "mean per-read die queueing delay (open loop)"),
    "read_chan_wait_us": Metric("us", "mean per-read channel-bus wait (lattice model)"),
    "retries_per_read": Metric("retries", "mean read-retry senses per read"),
    # ---- capacity / relocation ----
    "capacity_gib": Metric("GiB", "usable capacity at current block modes"),
    "capacity_loss_gib": Metric("GiB", "capacity surrendered to low-density modes"),
    "migrated_pages": Metric("pages", "pages moved by conversion/GC/reclaim"),
    "erases": Metric("erases", "block erases performed"),
    "conversions": Metric("conversions", "(3,3) from-mode x to-mode block conversions",
                          scalar=False),
    "reads": Metric("reads", "user reads served"),
    "writes": Metric("writes", "user pages written"),
    # ---- faults (DESIGN.md §2D) ----
    "uncorrectable_reads": Metric("reads", "reads past the retry budget (ECC recovery)"),
    "prog_fails": Metric("failures", "failed page programs (re-placed)"),
    "erase_fails": Metric("failures", "failed erases (block retired)"),
    "dropped_writes": Metric("writes", "writes lost to allocation exhaustion"),
    "bad_blocks": Metric("blocks", "blocks retired to the bad-block map"),
    # ---- endurance / WAF (DESIGN.md §2E) ----
    "user_pages": Metric("pages", "host page programs (the WAF denominator)"),
    "reloc_pages": Metric("pages", "physical relocation programs (ftl._place_pages)"),
    "waf": Metric("ratio", "write amplification = (user + reloc) / user pages"),
    "pe_mean": Metric("cycles", "mean P/E count over live blocks"),
    "pe_variance": Metric("cycles^2", "P/E-count variance over live blocks "
                                      "(wear-levelling quality)"),
    "pe_max": Metric("cycles", "worst-block P/E count"),
    "pe_mean_by_mode": Metric("cycles", "(3,) mean P/E per current block mode",
                              scalar=False),
    "tbw_gib": Metric("GiB", "projected total-bytes-written at rated QLC "
                             "endurance over measured WAF"),
    "dwpd": Metric("DWPD", "drive writes per day at the observed host rate"),
    "lifetime_years": Metric("years", "projected years to rated wear at the "
                                      "observed host rate (0 = no host writes)"),
    # ---- observability (DESIGN.md §7.4) ----
    "lat_mode_counts": Metric("reads", "(3, N_LAT_BINS) per-mode read histogram",
                              scalar=False),
    "lat_attrib_us": Metric("us", "(3, N_COMPONENTS) latency attribution sums",
                            scalar=False),
    "tail_retry_share": Metric("share", "(3,) retry share of each mode's p99 tail",
                               scalar=False),
    "conversion_events": Metric("conversions", "(3,3) conversions decoded from the "
                                               "event ring", scalar=False),
    "obs_events_total": Metric("events", "events emitted into the ring"),
    "obs_events_dropped": Metric("events", "ring overwrites (capacity overflow)"),
    # ---- wear-correlated faults / rebuild / spare pool (DESIGN.md §2D) ----
    "rebuilds": Metric("rebuilds", "die-parity stripe rebuilds of uncorrectable reads"),
    "data_loss": Metric("stripes", "second fault during rebuild: unreconstructable"),
    "degraded_writes": Metric("writes", "host writes refused in read-only degraded mode"),
    "spares_total": Metric("blocks", "over-provisioning spare pool size (-1 = unbounded)"),
    "spares_remaining": Metric("blocks", "spare blocks left (-1 = unbounded)"),
    "spare_covered_gib": Metric("GiB", "retired capacity backfilled by the spare pool"),
    "effective_capacity_gib": Metric("GiB", "usable capacity incl. spare-pool backfill"),
    "degraded": Metric("flag", "1.0 = spare pool exhausted, device read-only"),
}


def units() -> dict[str, str]:
    """name → unit for every registered metric."""
    return {k: m.unit for k, m in SCHEMA.items()}


def row_units() -> dict[str, str]:
    """name → unit for scalar metrics only — the sweep-row flattening order."""
    return {k: m.unit for k, m in SCHEMA.items() if m.scalar}


def describe(name: str) -> Metric:
    return SCHEMA[name]
