"""In-scan observability (DESIGN.md §7.4): latency attribution, conversion
event tracing and windowed time-series telemetry.

RARO's argument is causal — read slowdown comes from retries, so conversion
should fire only when hot data sits in high-retry QLC blocks — and end-of-run
aggregates can't show *which component* of p99 is retry-induced, *which
trigger* caused each conversion, or *when* retry storms happen. This module
adds three jit/vmap/shard_map-safe instruments, all static-shape accumulator
leaves on :class:`repro.ssdsim.state.SSDState`:

1. **Latency component decomposition** (``obs_lat_mode``, ``obs_lat_comp``):
   every recorded user read is split into queue / sense / retry-penalty /
   transfer time and binned — by its *total* recorded latency, reusing the
   :mod:`repro.ssdsim.telemetry` log-spaced bin geometry — per source flash
   mode. ``obs_lat_mode[m]`` counts reads of mode ``m`` per latency bin (the
   per-mode count histograms sum over modes to ``lat_hist`` bit-exactly:
   identical bin indices, integer-valued f32 adds); ``obs_lat_comp[m, c, b]``
   accumulates component ``c``'s microseconds over the reads in (mode, bin),
   so "retries contribute X µs of QLC p99" is a direct readout
   (:func:`tail_attribution`).

2. **Conversion/GC/reclaim event ring buffer** (``obs_events``,
   ``obs_ev_count``): a fixed-capacity ring recorded inside the scan at
   every relocation site. Each event carries sim-time, block id (-1 for
   page-granular conversions), from/to mode, a trigger reason code, the
   Eq.-3 mean retry estimate of the pages moved, and the valid page count.
   Overwrite-oldest semantics: the write cursor is ``obs_ev_count mod
   capacity`` and ``obs_ev_count`` keeps the true total, so truncation is
   always explicit (``dropped = max(total - capacity, 0)``).

3. **Windowed time series** (``obs_ts``): reads / retries / queue delay /
   writes / conversions / erases / migrated pages / uncorrectables /
   relocation pages (the windowed-WAF numerator) bucketed by simulated-time
   window (``cfg.obs_window_ms`` per window, ``cfg.obs_windows`` windows; the
   final window absorbs everything past the covered range, again explicit
   rather than silent). Retry storms and conversion waves show up as
   trajectories instead of totals.

Cost model (``cfg.obs_level``): ``"off"`` traces **no** observability ops at
all — every obs leaf is zero-length, so the scan carry and compiled program
are unchanged up to empty arrays (the PR 4/5 regression gate guards the
claim). ``"counters"`` adds the per-mode count histograms and the time
series (a handful of scatter-adds per chunk). ``"full"`` adds the component
decomposition and the event ring buffer.

Host-side decoders (numpy, usable on device_get'ed sweep leaves) live at the
bottom: :func:`decode_events`, :func:`event_conversion_matrix`,
:func:`decode_timeseries`, :func:`decomposition`, :func:`tail_attribution`.
The Chrome-trace exporter builds on them in
:mod:`repro.ssdsim.trace_export`.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import modes
from repro.ssdsim import geometry, telemetry

# --------------------------- instrument geometry ---------------------------

LEVELS = ("off", "counters", "full")

# latency components, in recorded-latency order: queueing delay behind the
# die, the base sense, the extra senses bought by retries, the wait for the
# channel bus (transfer queueing — nonzero only under the lattice model),
# the channel transfer service itself, and the die-parity rebuild critical
# path of uncorrectable reads recovered via the stripe (DESIGN.md §2D; zero
# mass unless ``parity_rebuild`` is armed)
COMP_QUEUE = 0
COMP_SENSE = 1
COMP_RETRY = 2
COMP_CHANWAIT = 3
COMP_XFER = 4
COMP_REBUILD = 5
N_COMPONENTS = 6
COMPONENT_NAMES = ("queue", "sense", "retry", "chan_wait", "transfer",
                   "rebuild")

# event record fields (one f32 row per event; ids/counts are small integers,
# exact in f32, which keeps the ring a single dense array — one scatter)
EV_T_MS = 0
EV_BLOCK = 1  # -1 for page-granular conversion events
EV_FROM = 2
EV_TO = 3
EV_REASON = 4
EV_RETRY = 5  # Eq.-3 mean retry estimate over the pages moved
EV_PAGES = 6  # valid pages moved
N_EV_FIELDS = 7

# trigger reason codes
REASON_CONV_PAGE = 0  # policy-triggered page-granular conversion (Fig. 11)
REASON_GC = 1  # fused multi-victim GC relocation
REASON_RECLAIM = 2  # elastic capacity recovery demotion (paper §IV-E)
REASON_CONV_BLOCK = 3  # direct block conversion (ftl.migrate_block API)
REASON_BAD_BLOCK = 4  # erase failure -> bad-block retirement (DESIGN.md §2D)
REASON_NAMES = ("conversion", "gc", "reclaim", "block_conversion",
                "bad_block_retire")

# time-series rows
TS_READS = 0
TS_RETRIES = 1
TS_QUEUE_MS = 2
TS_WRITES = 3
TS_CONVERSIONS = 4  # n_conversions increments (pages for page-granular ops)
TS_ERASES = 5
TS_MIGRATED = 6
TS_UNCORR = 7  # uncorrectable reads (ECC recovery events, DESIGN.md §2D)
TS_RELOC = 8  # relocation-programmed pages (WAF numerator, DESIGN.md §2E)
N_SERIES = 9
SERIES_NAMES = (
    "reads", "retries", "queue_ms", "writes", "conversions", "erases",
    "migrated_pages", "uncorrectable", "reloc_pages",
)


def enabled(cfg: geometry.SimConfig) -> bool:
    """Counters or better are being collected (trace-time gate)."""
    return cfg.obs_level != "off"


def full(cfg: geometry.SimConfig) -> bool:
    """Component decomposition + event ring are being collected."""
    return cfg.obs_level == "full"


def init_leaves(cfg: geometry.SimConfig) -> dict:
    """Zero accumulators for ``state.init_state`` — shapes depend only on
    the (static) config, and collapse to zero-length when an instrument is
    off so the disabled path carries nothing through the scan."""
    if cfg.obs_level not in LEVELS:
        raise ValueError(
            f"obs_level must be one of {LEVELS}, got {cfg.obs_level!r}"
        )
    n_mode = modes.N_MODES if enabled(cfg) else 0
    n_full = modes.N_MODES if full(cfg) else 0
    cap = int(cfg.obs_event_capacity) if full(cfg) else 0
    win = int(cfg.obs_windows) if enabled(cfg) else 0
    if full(cfg) and cap < 1:
        raise ValueError("obs_event_capacity must be >= 1 at obs_level='full'")
    if enabled(cfg) and win < 1:
        raise ValueError("obs_windows must be >= 1 when observability is on")
    return dict(
        obs_lat_mode=jnp.zeros((n_mode, telemetry.N_LAT_BINS), jnp.float32),
        obs_lat_comp=jnp.zeros(
            (n_full, N_COMPONENTS, telemetry.N_LAT_BINS), jnp.float32
        ),
        obs_events=jnp.zeros((cap, N_EV_FIELDS), jnp.float32),
        obs_ev_count=jnp.int32(0),
        obs_ts=jnp.zeros((win, N_SERIES), jnp.float32),
    )


# ------------------------------ in-scan hooks ------------------------------


def _window_of(cfg: geometry.SimConfig, t_ms):
    """Window index for a sim time; the last window absorbs overflow."""
    w = jnp.floor(jnp.asarray(t_ms, jnp.float32) / cfg.obs_window_ms)
    return jnp.clip(w.astype(jnp.int32), 0, int(cfg.obs_windows) - 1)


def record_reads(s, cfg: geometry.SimConfig, *, mode, rd, lat_us, queue_us,
                 sense_us, retry_us, chanw_us, xfer_us, retries, t_ms,
                 uncorr=None, rebuild_us=None):
    """Per-read instruments for one chunk (engine read path).

    ``mode``/``lat_us``/... are per-lane arrays; ``rd`` masks user reads;
    ``t_ms`` is the per-lane sim time used for windowing (departure time
    open-loop, the chunk clock closed-loop). ``chanw_us`` is the transfer
    *queueing* behind the channel bus — split from the transfer service so
    bus contention is attributable separately (zero under the legacy
    channel model, where transfer never queues). ``uncorr`` (optional bool
    lanes, fault injection on) feeds the uncorrectable-read series.
    Masked-out lanes are dropped via out-of-range indices — the repo-wide
    scatter discipline.
    """
    if not enabled(cfg):
        return s
    nbin = telemetry.N_LAT_BINS
    b = telemetry.latency_bin(lat_us)
    m = jnp.clip(mode, 0, modes.N_MODES - 1)
    # per-mode count histogram: same bin index as telemetry.record uses for
    # lat_hist, so summing over modes reproduces it bit-exactly
    mode_drop = jnp.where(rd, m, modes.N_MODES)
    lat_mode = s.obs_lat_mode.at[mode_drop, b].add(1.0, mode="drop")

    # time series: reads / retries / queue per window of each read's own time
    w = jnp.where(rd, _window_of(cfg, t_ms), int(cfg.obs_windows))
    ts = s.obs_ts
    ts = ts.at[w, TS_READS].add(1.0, mode="drop")
    ts = ts.at[w, TS_RETRIES].add(
        jnp.asarray(retries, jnp.float32), mode="drop"
    )
    ts = ts.at[w, TS_QUEUE_MS].add(
        jnp.asarray(queue_us, jnp.float32) / 1000.0, mode="drop"
    )
    if uncorr is not None:
        ts = ts.at[w, TS_UNCORR].add(
            jnp.asarray(uncorr, jnp.float32), mode="drop"
        )
    s = s._replace(obs_lat_mode=lat_mode, obs_ts=ts)

    if not full(cfg):
        return s
    comp = s.obs_lat_comp
    pairs = [
        (COMP_QUEUE, queue_us),
        (COMP_SENSE, sense_us),
        (COMP_RETRY, retry_us),
        (COMP_CHANWAIT, chanw_us),
        (COMP_XFER, xfer_us),
    ]
    if rebuild_us is not None:
        pairs.append((COMP_REBUILD, rebuild_us))
    for c, v in pairs:
        comp = comp.at[mode_drop, c, b].add(
            jnp.asarray(v, jnp.float32), mode="drop"
        )
    return s._replace(obs_lat_comp=comp)


def record_chunk(s, cfg: geometry.SimConfig, *, t_ms, writes, conversions,
                 erases, migrated, reloc=None):
    """Chunk-granularity series (background-FTL counter deltas): everything
    in the chunk lands in the window of the chunk's end-of-step clock.
    ``reloc`` (optional) feeds the relocation-pages series behind the
    windowed WAF readout of :func:`decode_timeseries`."""
    if not enabled(cfg):
        return s
    w = _window_of(cfg, t_ms)
    ts = s.obs_ts
    rows = [
        (TS_WRITES, writes),
        (TS_CONVERSIONS, conversions),
        (TS_ERASES, erases),
        (TS_MIGRATED, migrated),
    ]
    if reloc is not None:
        rows.append((TS_RELOC, reloc))
    for row, v in rows:
        ts = ts.at[w, row].add(jnp.asarray(v, jnp.float32))
    return s._replace(obs_ts=ts)


def record_events(s, cfg: geometry.SimConfig, *, mask, block, from_mode,
                  to_mode, reason, retry_est, pages):
    """Append ``mask``-ed events to the ring buffer (relocation sites).

    All arguments are (K,) lanes (``reason`` may be a python int). Events
    are written at ``(obs_ev_count + rank) mod capacity`` in lane order, so
    the ring holds the most recent ``capacity`` events and the counter keeps
    the true total — overwrite-oldest with explicit truncation.
    """
    if not full(cfg):
        return s
    cap = s.obs_events.shape[0]
    mask = jnp.asarray(mask, bool)
    rank = jnp.cumsum(mask.astype(jnp.int32)) - 1
    pos = (s.obs_ev_count + rank) % cap
    idx = jnp.where(mask, pos, cap)  # cap = out of range -> dropped
    rows = jnp.stack(
        [
            jnp.broadcast_to(jnp.asarray(v, jnp.float32), mask.shape)
            for v in (
                s.clock_ms, block, from_mode, to_mode, reason, retry_est,
                pages,
            )
        ],
        axis=-1,
    )
    return s._replace(
        obs_events=s.obs_events.at[idx].set(rows, mode="drop"),
        obs_ev_count=s.obs_ev_count + mask.sum().astype(jnp.int32),
    )


# ----------------------------- host decoders -------------------------------


def decode_events(s, cfg: geometry.SimConfig):
    """Decode the ring into structured records, oldest first.

    Returns ``(records, total, dropped)``: ``records`` is a list of dicts
    (one per event still in the ring), ``total`` the true number of events
    emitted, ``dropped`` how many were overwritten (``total - len(records)``).
    Works on device or numpy leaves (the sweep runner hands numpy).
    """
    ev = np.asarray(s.obs_events, np.float32)
    total = int(np.asarray(s.obs_ev_count))
    cap = ev.shape[0]
    if cap == 0 or total == 0:
        return [], total, total
    n = min(total, cap)
    # ring order: the oldest retained event sits at total mod cap when the
    # ring has wrapped, else at 0
    start = total % cap if total > cap else 0
    order = (start + np.arange(n)) % cap
    records = []
    for row in ev[order]:
        reason = int(row[EV_REASON])
        records.append(
            dict(
                t_ms=float(row[EV_T_MS]),
                block=int(row[EV_BLOCK]),
                from_mode=int(row[EV_FROM]),
                to_mode=int(row[EV_TO]),
                from_mode_name=modes.MODE_NAMES[int(row[EV_FROM])],
                to_mode_name=modes.MODE_NAMES[int(row[EV_TO])],
                reason=reason,
                reason_name=REASON_NAMES[reason],
                retry_est=float(row[EV_RETRY]),
                pages=int(row[EV_PAGES]),
                # the increment this event contributed to n_conversions:
                # page-granular conversions count pages, block ops count 1
                conversions=int(row[EV_PAGES]) if reason == REASON_CONV_PAGE
                else 1,
            )
        )
    return records, total, total - n


def event_conversion_matrix(records) -> np.ndarray:
    """(3, 3) from-mode x to-mode conversion counts reconstructed from
    decoded events — equals ``SSDState.n_conversions`` whenever the ring
    did not overflow (``dropped == 0``)."""
    m = np.zeros((modes.N_MODES, modes.N_MODES), np.float64)
    for r in records:
        m[r["from_mode"], r["to_mode"]] += r["conversions"]
    return m


def decode_timeseries(s, cfg: geometry.SimConfig) -> dict:
    """Windowed series as a dict of numpy arrays (+ derived means)."""
    ts = np.asarray(s.obs_ts, np.float64)
    out = {"window_start_ms": np.arange(ts.shape[0]) * cfg.obs_window_ms,
           "window_ms": float(cfg.obs_window_ms)}
    for i, name in enumerate(SERIES_NAMES):
        out[name] = ts[:, i]
    reads = np.maximum(out["reads"], 1.0)
    out["mean_queue_delay_us"] = out["queue_ms"] / reads * 1e3
    out["retries_per_read"] = out["retries"] / reads
    # windowed write amplification (DESIGN.md §2E): per-window delta WAF,
    # pinned to 1.0 in windows with no host writes (idle or read-only)
    writes = out["writes"]
    out["waf_window"] = np.where(
        writes > 0,
        (writes + out["reloc_pages"]) / np.maximum(writes, 1.0),
        1.0,
    )
    return out


def decomposition(s, cfg: geometry.SimConfig) -> dict:
    """Per-mode latency decomposition: read counts and per-component µs per
    latency bin, plus the telemetry bin edges."""
    return dict(
        counts=np.asarray(s.obs_lat_mode, np.float64),
        component_us=np.asarray(s.obs_lat_comp, np.float64),
        edges_us=telemetry.bin_edges_us(),
        components=COMPONENT_NAMES,
        modes=modes.MODE_NAMES,
    )


def tail_attribution(s, cfg: geometry.SimConfig, q: float = 0.99) -> dict:
    """Component shares of the latency mass at and above each mode's
    q-quantile bin — the "retries contribute X µs of QLC p99" readout.

    Returns per-mode dicts: the quantile's bin edge, the reads in the tail,
    and per-component µs totals and shares over those tail reads. Modes with
    no reads report zeros.
    """
    counts = np.asarray(s.obs_lat_mode, np.float64)
    comp = np.asarray(s.obs_lat_comp, np.float64)
    out = {}
    for m, name in enumerate(modes.MODE_NAMES):
        if counts.shape[0] == 0 or counts[m].sum() <= 0:
            out[name] = dict(
                tail_reads=0.0, tail_edge_us=0.0,
                component_us={c: 0.0 for c in COMPONENT_NAMES},
                component_share={c: 0.0 for c in COMPONENT_NAMES},
            )
            continue
        b = telemetry.quantile_bin(counts[m], q)
        tail_us = comp[m, :, b:].sum(axis=1) if comp.shape[0] else np.zeros(
            N_COMPONENTS
        )
        total = max(tail_us.sum(), 1e-12)
        out[name] = dict(
            tail_reads=float(counts[m, b:].sum()),
            tail_edge_us=float(telemetry.bin_edges_us()[b]),
            component_us={c: float(v)
                          for c, v in zip(COMPONENT_NAMES, tail_us)},
            component_share={c: float(v / total)
                             for c, v in zip(COMPONENT_NAMES, tail_us)},
        )
    return out


def summary(s, cfg: geometry.SimConfig) -> dict:
    """JSON-safe flat additions for ``engine.summarize`` (floats and nested
    lists only — the sweep's exact-equality checker ``np.asarray``'s every
    value, so no nested dicts).

    Keys (present at ``counters`` and up; decomposition/event keys need
    ``full``):

    - ``lat_mode_counts`` — (3, N_LAT_BINS) per-mode read-count histogram
    - ``lat_attrib_us`` — (3, N_COMPONENTS) total µs per mode x component
    - ``tail_retry_share`` — (3,) retry share of each mode's p99 tail mass
    - ``conversion_events`` — (3, 3) decoded from-x-to event counts (equals
      ``conversions`` when ``obs_events_dropped`` is 0)
    - ``obs_events_total`` / ``obs_events_dropped`` — ring truncation, explicit
    """
    if not enabled(cfg):
        return {}
    out = {"lat_mode_counts": np.asarray(s.obs_lat_mode, np.float64).tolist()}
    if not full(cfg):
        return out
    comp = np.asarray(s.obs_lat_comp, np.float64)
    attrib = tail_attribution(s, cfg)
    records, total, dropped = decode_events(s, cfg)
    out.update(
        lat_attrib_us=comp.sum(axis=2).tolist(),
        tail_retry_share=[
            attrib[name]["component_share"]["retry"]
            for name in modes.MODE_NAMES
        ],
        conversion_events=event_conversion_matrix(records).tolist(),
        obs_events_total=float(total),
        obs_events_dropped=float(dropped),
    )
    return out
