"""Per-chunk policy evaluation for the three schemes (paper §V-A):

  BASELINE — multi-read-retry QLC, no mode awareness: never migrates.
  HOTNESS  — temperature-only SLC-TLC-QLC conversion (comparison scheme).
  RARO     — temperature AND Eq.-3 retry thresholds (Table II).

The policies see exactly what the paper's FTL sees on the read path: the
pages read in this chunk (the per-read trigger pipeline of Fig. 11,
vectorized), and emit -1-padded lpn lists per target mode for
``ftl.migrate_pages``.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core import hotness, modes, policy
from repro.ssdsim import geometry


def thresholds_for(cfg: geometry.SimConfig, pe_cycles):
    if cfg.r2_override >= 0:
        return policy.Thresholds(jnp.int32(cfg.r1), jnp.int32(cfg.r2_override))
    th = policy.stage_thresholds(pe_cycles, r1=cfg.r1)
    return th


def select_migrations(cfg: geometry.SimConfig, uniq_lpns, page_mode, page_retries,
                      page_heat, page_ok, pe_cycles):
    """Select up to M pages per target mode to migrate this chunk.

    Returns dict {mode: (M,) int32 lpns, -1-padded}, hottest-first.
    """
    M = cfg.migrate_pages_per_chunk
    cls = hotness.classify(page_heat, cfg.heat)

    if cfg.policy == geometry.RARO:
        th = thresholds_for(cfg, pe_cycles)
        target = policy.migration_decision(page_mode, cls, page_retries, th)
    elif cfg.policy == geometry.HOTNESS:
        target = policy.hotness_only_decision(page_mode, cls)
    else:  # BASELINE
        target = page_mode

    out = {}
    for tgt in (modes.SLC, modes.TLC):
        trig = page_ok & (target == tgt) & (page_mode != tgt) & (page_mode > tgt)
        score = jnp.where(trig, page_heat, -jnp.inf)
        k = min(M, score.shape[0])
        v, i = lax.top_k(score, k)
        sel = jnp.where(v > -jnp.inf, uniq_lpns[i], -1).astype(jnp.int32)
        if k < M:
            sel = jnp.pad(sel, (0, M - k), constant_values=-1)
        out[tgt] = sel
    return out
