"""Per-chunk policy evaluation for the three schemes (paper §V-A):

  BASELINE — multi-read-retry QLC, no mode awareness: never migrates.
  HOTNESS  — temperature-only SLC-TLC-QLC conversion (comparison scheme).
  RARO     — temperature AND Eq.-3 retry thresholds (Table II).

The policies see exactly what the paper's FTL sees on the read path: the
pages read in this chunk (the per-read trigger pipeline of Fig. 11,
vectorized), and emit -1-padded lpn lists per target mode for
``ftl.migrate_pages``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
from jax import lax

from repro.core import hotness, modes, policy
from repro.ssdsim import geometry


class RunKnobs(NamedTuple):
    """Batchable per-run knobs (scalars, may be traced/vmapped).

    These are the SimConfig fields the sweep runner batches through
    ``jax.vmap``: unlike ``policy`` or the geometry they never change trace
    shapes, so a whole grid of (r1, r2_override, initial_pe, arrival_scale)
    runs shares one compiled program (DESIGN.md §7.3).
    """

    r1: jnp.ndarray
    r2_override: jnp.ndarray  # < 0: use the paper's stage schedule
    initial_pe: jnp.ndarray
    # offered-load multiplier for open-loop traces: effective arrival time
    # = trace arrival_ms / arrival_scale, so scale 2.0 doubles the offered
    # IOPS of the same trace. None (not a pytree leaf) or 1.0 replays the
    # trace's own timeline; ignored entirely for closed-loop traces.
    arrival_scale: jnp.ndarray | None = None
    # fault-injection axis (DESIGN.md §2D): all four are set together (see
    # ``faults.params_for``) or all left None, which keeps the fault ops out
    # of the trace entirely. Traced rates of exactly 0.0 (with
    # max_read_retries = -1) reproduce the fault-free outputs bit for bit,
    # so a sweep can mix fault-free and faulty runs in one compiled program.
    prog_fail_rate: jnp.ndarray | None = None
    erase_fail_rate: jnp.ndarray | None = None
    max_read_retries: jnp.ndarray | None = None
    fault_seed: jnp.ndarray | None = None
    # wear-coupled reliability axes (ride the fault axis above; each falls
    # back to its static SimConfig field when left None, so older callers
    # that arm only the four PR 7 fields are unchanged). Neutral values —
    # rate 0.0, slope 0.0, rebuild 0, spares < 0 — trace ops that reproduce
    # the flat-rate/infinite-spare outputs bit for bit.
    read_fail_rate: jnp.ndarray | None = None  # f32 per-read uncorrectable
    fault_wear_slope: jnp.ndarray | None = None  # f32 wear-curve gain
    parity_rebuild: jnp.ndarray | None = None  # i32 0/1 rebuild recovery
    spare_blocks: jnp.ndarray | None = None  # i32; < 0 = unbounded pool
    # GC victim-objective axis (DESIGN.md §2E): int32 code per
    # ``reclaim.GC_OBJECTIVE_CODES`` (0 = min_valid, 1 = lifespan). None
    # keeps the static ``cfg.gc_objective`` formula; code 0 traces the
    # identical selection ops as the static default, so a sweep can mix
    # objectives in one compiled program without perturbing the baseline.
    gc_objective: jnp.ndarray | None = None


def thresholds_for(cfg: geometry.SimConfig, pe_cycles, knobs: RunKnobs | None = None):
    if knobs is not None:
        # Traced override: resolve r2 per element so a vmapped batch can mix
        # explicit-R2 runs with stage-schedule runs.
        stage_th = policy.stage_thresholds(pe_cycles)
        r2 = jnp.where(knobs.r2_override >= 0, jnp.int32(knobs.r2_override), stage_th.r2)
        return policy.Thresholds(jnp.int32(knobs.r1), r2)
    if cfg.r2_override >= 0:
        return policy.Thresholds(jnp.int32(cfg.r1), jnp.int32(cfg.r2_override))
    th = policy.stage_thresholds(pe_cycles, r1=cfg.r1)
    return th


def select_migrations(cfg: geometry.SimConfig, uniq_lpns, page_mode, page_retries,
                      page_heat, page_ok, pe_cycles, knobs: RunKnobs | None = None):
    """Select up to M pages per target mode to migrate this chunk.

    Returns dict {mode: (M,) int32 lpns, -1-padded}, hottest-first.
    """
    M = cfg.migrate_pages_per_chunk
    cls = hotness.classify(page_heat, cfg.heat)

    if cfg.policy == geometry.RARO:
        th = thresholds_for(cfg, pe_cycles, knobs)
        target = policy.migration_decision(page_mode, cls, page_retries, th)
    elif cfg.policy == geometry.HOTNESS:
        target = policy.hotness_only_decision(page_mode, cls)
    else:  # BASELINE
        target = page_mode

    out = {}
    for tgt in (modes.SLC, modes.TLC):
        trig = page_ok & (target == tgt) & (page_mode != tgt) & (page_mode > tgt)
        score = jnp.where(trig, page_heat, -jnp.inf)
        k = min(M, score.shape[0])
        v, i = lax.top_k(score, k)
        sel = jnp.where(v > -jnp.inf, uniq_lpns[i], -1).astype(jnp.int32)
        if k < M:
            sel = jnp.pad(sel, (0, M - k), constant_values=-1)
        out[tgt] = sel
    return out
