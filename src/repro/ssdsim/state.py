"""Simulator state — a flat pytree of arrays so the whole engine jits/scans.

Physical page addressing: slot = block * slots_per_block + offset. A block
programmed in TLC/SLC mode only uses the first pages_per_block(mode) offsets.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import modes
from repro.ssdsim import geometry, obs, telemetry

FREE = 0
OPEN = 1
FULL = 2
# Retired: the block failed an erase and left service permanently
# (DESIGN.md §2D). Never FREE again, never allocated, zero capacity.
BAD = 3

# Sentinel pool size for cfg.spare_blocks < 0: an unbounded spare pool
# (int32 max — no realizable retirement count reaches it), which keeps the
# degraded-mode predicate traced-False and the PR 7 accounting bit-exact.
SPARE_UNLIMITED = 2**31 - 1


class SSDState(NamedTuple):
    # mapping
    l2p: jnp.ndarray  # (L,) int32 logical -> physical slot (-1 unmapped)
    p2l: jnp.ndarray  # (S,) int32 physical slot -> logical (-1 invalid)
    page_write_ms: jnp.ndarray  # (S,) float32 sim-clock time of program

    # per-block
    block_mode: jnp.ndarray  # (B,) int32 SLC/TLC/QLC
    block_state: jnp.ndarray  # (B,) int32 FREE/OPEN/FULL
    block_pe: jnp.ndarray  # (B,) int32 P/E cycles
    block_reads: jnp.ndarray  # (B,) int32 reads since program (disturb)
    block_next: jnp.ndarray  # (B,) int32 next free offset
    block_valid: jnp.ndarray  # (B,) int32 valid page count
    block_cold_age: jnp.ndarray  # (B,) int32 epochs since any hot/warm access
    # grown bad-block map (DESIGN.md §2D): True iff the block failed an
    # erase and was retired (block_state == BAD, by invariant). A separate
    # leaf so factory bad blocks / host-visible retirement lists have a
    # stable home independent of the state machine.
    block_bad: jnp.ndarray  # (B,) bool

    # retirement accounting (exact, maintained by ftl._erase_many like
    # free_count; invariant: bad_count == (block_state == BAD).sum())
    bad_count: jnp.ndarray  # int32 scalar — retired blocks

    # over-provisioning spare pool (DESIGN.md §2D): every retirement
    # consumes one spare until the pool runs dry; an exhausted pool flips
    # the engine into read-only degraded mode (writes dropped + counted).
    # spare_total is a constant leaf (SPARE_UNLIMITED for the unbounded
    # PR 7 accounting); invariant: spare_count == max(total - bad, 0).
    spare_total: jnp.ndarray  # int32 scalar — configured pool size
    spare_count: jnp.ndarray  # int32 scalar — spares remaining

    # heat (logical)
    heat: jnp.ndarray  # (L,) float32

    # allocation cursors
    open_user: jnp.ndarray  # (n_dies,) int32 open block per die (-1 none)
    open_mig: jnp.ndarray  # (3,) int32 open migration block per mode (-1)

    # free-pool bookkeeping (maintained incrementally by erase/alloc so the
    # hot path never rescans block_state; invariant checked by the tests:
    # free_count == (block_state == FREE).sum())
    free_count: jnp.ndarray  # int32 scalar — exact number of FREE blocks
    free_hint: jnp.ndarray  # (n_dies,) int32 — a (possibly stale) free block
    #   per die, refreshed on erase; consumers verify against block_state and
    #   fall back to a full scan only when the hint is dead

    # timing — the (channel, die, plane) resource lattice (DESIGN.md §2C).
    # A die owns sense/program/erase occupancy; the channel bus serializes
    # page transfers across its dies (chan_model="lattice"; under "legacy"
    # the channel clocks stay 0 and a die is the historical one-clock LUN).
    clock_ms: jnp.ndarray  # f32 scalar — simulated time
    die_busy_ms: jnp.ndarray  # (n_dies,) f32 — cumulative busy time
    chan_busy_ms: jnp.ndarray  # (n_channels,) f32 — cumulative transfer time
    # open-loop arrival model (DESIGN.md §2C): absolute sim time at which
    # each die next becomes available. Requests arriving earlier queue
    # (FCFS per die); background work (migrations/GC/erase) pushes it
    # forward too, so reads block behind FTL tasks. Stays 0 in closed loop.
    die_avail_ms: jnp.ndarray  # (n_dies,) f32 — busy_until clock per die
    # absolute sim time each channel bus next becomes free for a transfer
    # (lattice open loop only; stays 0 under chan_model="legacy")
    chan_avail_ms: jnp.ndarray  # (n_channels,) f32 — busy_until per channel

    # telemetry
    lat_hist: jnp.ndarray  # (telemetry.N_LAT_BINS,) f32 read-latency histogram
    w_lat_hist: jnp.ndarray  # (telemetry.N_LAT_BINS,) f32 write-latency histogram

    # observability accumulators (DESIGN.md §7.4; shapes collapse to
    # zero-length when the instrument is off, so obs_level="off" carries
    # nothing extra through the scan)
    obs_lat_mode: jnp.ndarray  # (3|0, N_LAT_BINS) per-mode read counts
    obs_lat_comp: jnp.ndarray  # (3|0, N_COMPONENTS, N_LAT_BINS) µs sums
    obs_events: jnp.ndarray  # (capacity|0, N_EV_FIELDS) f32 event ring
    obs_ev_count: jnp.ndarray  # i32 scalar — true total events emitted
    obs_ts: jnp.ndarray  # (windows|0, N_SERIES) windowed time series

    # counters (f32 scalars; summed per-chunk so precision is fine)
    svc_sum_ms: jnp.ndarray  # total recorded user-read latency (queueing
    #   delay when open-loop, + sense/retry + xfer)
    q_sum_ms: jnp.ndarray  # total read queueing delay (0 in closed loop)
    chanq_sum_ms: jnp.ndarray  # total read channel-wait (transfer queueing
    #   behind the bus; nonzero only under the lattice open-loop model)
    n_reads: jnp.ndarray
    n_writes: jnp.ndarray
    n_retries: jnp.ndarray
    n_migrated_pages: jnp.ndarray
    # physical relocation programs, counted at the single placement core
    # (ftl._place_pages) so GC / reclaim / conversion / prog-fail
    # re-placement all land in one WAF denominator-exact counter:
    # WAF = (n_writes + n_reloc_pages) / n_writes (DESIGN.md §2E)
    n_reloc_pages: jnp.ndarray
    n_erases: jnp.ndarray
    n_conversions: jnp.ndarray  # (3,3) from-mode x to-mode counts
    # fault/recovery counters (DESIGN.md §2D; all stay exactly 0.0 on the
    # fault-free path, which the zero-fault equivalence test pins)
    n_uncorrectable: jnp.ndarray  # reads past the retry budget (ECC recovery)
    n_prog_fails: jnp.ndarray  # failed page programs (re-placed)
    n_erase_fails: jnp.ndarray  # failed erases (block retired)
    n_dropped_writes: jnp.ndarray  # writes/re-placements lost to allocation
    #   exhaustion under retirement pressure (the stalled-queue path)
    n_rebuilds: jnp.ndarray  # die-parity stripe reconstructions (uncorrectable
    #   reads recovered via peers; only with parity_rebuild armed)
    n_data_loss: jnp.ndarray  # rebuilds hit by a second uncorrectable among
    #   the peer reads — the stripe is unreconstructable (true data loss)
    n_degraded_writes: jnp.ndarray  # writes refused in read-only degraded
    #   mode (spare pool exhausted; mapping untouched)


def init_state(cfg: geometry.SimConfig, initial_pe=None,
               spare_blocks=None) -> SSDState:
    """Pre-filled device: L logical pages written sequentially into QLC
    blocks (LUN-striped by block id), remaining blocks free. Matches the
    paper's setup: 'Initially, the block types of the hybrid SSD are set to
    the QLC mode'.

    ``initial_pe`` optionally overrides ``cfg.initial_pe`` with a traced
    scalar so a batch of wear stages can share one jitted sweep (vmap over
    the run axis — see repro.experiments.sweep); ``spare_blocks`` does the
    same for ``cfg.spare_blocks`` (negative = unbounded pool).
    """
    B, S, L = cfg.n_blocks, cfg.n_slots, cfg.n_logical
    spb = cfg.slots_per_block
    assert L <= S, "working set must fit the device"
    n_full = L // spb  # fully used blocks
    rem = L - n_full * spb

    lpn = jnp.arange(L, dtype=jnp.int32)
    l2p = lpn  # block i//spb, offset i%spb -> slot == lpn
    p2l = jnp.full((S,), -1, jnp.int32).at[lpn].set(lpn)

    blk = jnp.arange(B, dtype=jnp.int32)
    used_full = blk < n_full
    part = (blk == n_full) & (rem > 0)
    block_state = jnp.where(used_full, FULL, jnp.where(part, OPEN, FREE)).astype(jnp.int32)
    block_next = jnp.where(used_full, spb, jnp.where(part, rem, 0)).astype(jnp.int32)
    block_valid = block_next

    free = block_state == FREE
    # lowest-numbered free block per LUN seeds the allocation hints
    hint = jax.ops.segment_min(
        jnp.where(free, blk, B), cfg.die_of_block(blk), num_segments=cfg.n_dies
    )
    free_hint = jnp.where(hint < B, hint, -1).astype(jnp.int32)

    # negative = unbounded pool; works for both the static int and a traced
    # per-run knob (the where stays shape-() either way)
    sb = jnp.asarray(
        cfg.spare_blocks if spare_blocks is None else spare_blocks, jnp.int32)
    spare_total = jnp.where(sb < 0, jnp.int32(SPARE_UNLIMITED), sb)

    return SSDState(
        l2p=l2p,
        p2l=p2l,
        page_write_ms=jnp.zeros((S,), jnp.float32),
        block_mode=jnp.full((B,), modes.QLC, jnp.int32),
        block_state=block_state,
        block_pe=jnp.full((B,), jnp.int32(cfg.initial_pe if initial_pe is None else initial_pe)),
        block_reads=jnp.zeros((B,), jnp.int32),
        block_next=block_next,
        block_valid=block_valid,
        block_cold_age=jnp.zeros((B,), jnp.int32),
        block_bad=jnp.zeros((B,), bool),
        bad_count=jnp.int32(0),
        spare_total=spare_total,
        spare_count=spare_total,
        heat=jnp.zeros((L,), jnp.float32),
        open_user=jnp.full((cfg.n_dies,), -1, jnp.int32),
        open_mig=jnp.full((3,), -1, jnp.int32),
        free_count=free.sum().astype(jnp.int32),
        free_hint=free_hint,
        lat_hist=jnp.zeros((telemetry.N_LAT_BINS,), jnp.float32),
        w_lat_hist=jnp.zeros((telemetry.N_LAT_BINS,), jnp.float32),
        **obs.init_leaves(cfg),
        clock_ms=jnp.float32(0.0),
        die_busy_ms=jnp.zeros((cfg.n_dies,), jnp.float32),
        chan_busy_ms=jnp.zeros((cfg.n_channels,), jnp.float32),
        die_avail_ms=jnp.zeros((cfg.n_dies,), jnp.float32),
        chan_avail_ms=jnp.zeros((cfg.n_channels,), jnp.float32),
        svc_sum_ms=jnp.float32(0.0),
        q_sum_ms=jnp.float32(0.0),
        chanq_sum_ms=jnp.float32(0.0),
        n_reads=jnp.float32(0.0),
        n_writes=jnp.float32(0.0),
        n_retries=jnp.float32(0.0),
        n_migrated_pages=jnp.float32(0.0),
        n_reloc_pages=jnp.float32(0.0),
        n_erases=jnp.float32(0.0),
        n_conversions=jnp.zeros((3, 3), jnp.float32),
        n_uncorrectable=jnp.float32(0.0),
        n_prog_fails=jnp.float32(0.0),
        n_erase_fails=jnp.float32(0.0),
        n_dropped_writes=jnp.float32(0.0),
        n_rebuilds=jnp.float32(0.0),
        n_data_loss=jnp.float32(0.0),
        n_degraded_writes=jnp.float32(0.0),
    )


def check_invariants(s: SSDState, cfg: geometry.SimConfig, where: str = "") -> None:
    """Assert full-state FTL consistency (host-side numpy; test helper).

    Checks the invariants every engine step and every relocation pass must
    preserve: l2p/p2l mutual consistency (a bijection on mapped pages),
    ``block_valid`` equal to the per-block recount of valid slots, valid
    slots confined to each block's programmed window, block metadata in
    range, exact incremental ``free_count``, free hints on their own LUN
    (stale hints are legal by design — consumers re-validate against
    ``block_state`` — but a hint never strays off its LUN or out of range),
    and open user/migration cursors pointing at OPEN blocks.
    """
    import numpy as np

    tag = f" [{where}]" if where else ""
    spb = cfg.slots_per_block
    B, L = cfg.n_blocks, cfg.n_logical
    l2p = np.asarray(s.l2p)
    p2l = np.asarray(s.p2l)

    # -- mapping bijection --
    mapped = l2p >= 0
    assert (l2p[mapped] < cfg.n_slots).all(), f"l2p out of range{tag}"
    assert (p2l[l2p[mapped]] == np.arange(L)[mapped]).all(), \
        f"l2p -> p2l mismatch{tag}"
    vslots = np.nonzero(p2l >= 0)[0]
    assert (p2l[vslots] < L).all(), f"p2l out of range{tag}"
    assert (l2p[p2l[vslots]] == vslots).all(), f"p2l -> l2p mismatch{tag}"

    # -- per-block accounting --
    bv = np.asarray(s.block_valid)
    counts = np.bincount(vslots // spb, minlength=B)
    assert (bv == counts).all(), \
        f"block_valid recount mismatch at {np.nonzero(bv != counts)[0][:8]}{tag}"
    bm = np.asarray(s.block_mode)
    bs = np.asarray(s.block_state)
    bn = np.asarray(s.block_next)
    assert ((bm >= 0) & (bm < modes.N_MODES)).all(), f"block_mode range{tag}"
    assert ((bs >= FREE) & (bs <= BAD)).all(), f"block_state range{tag}"
    ppb = geometry.pages_per_block_host(cfg)
    nonfree = bs != FREE
    assert (bn[nonfree] <= ppb[bm[nonfree]]).all(), f"block_next > pages{tag}"
    assert (bn >= bv).all(), f"valid pages exceed programmed pages{tag}"
    assert (bn[bs == FREE] == 0).all() and (bv[bs == FREE] == 0).all(), \
        f"FREE block with programmed/valid pages{tag}"

    # -- bad-block accounting (DESIGN.md §2D) --
    bad = np.asarray(s.block_bad)
    assert (bad == (bs == BAD)).all(), f"block_bad / block_state BAD mismatch{tag}"
    assert int(s.bad_count) == int(bad.sum()), \
        f"bad_count {int(s.bad_count)} != recount {int(bad.sum())}{tag}"
    assert (bn[bad] == 0).all() and (bv[bad] == 0).all(), \
        f"retired block with programmed/valid pages{tag}"
    # spare-pool accounting: every retirement consumed a spare until dry
    total, remaining = int(s.spare_total), int(s.spare_count)
    assert total >= 0, f"negative spare_total{tag}"
    assert remaining == max(total - int(bad.sum()), 0), \
        f"spare_count {remaining} != max({total} - {int(bad.sum())}, 0){tag}"
    # valid slots sit inside the programmed window of their block
    assert (vslots % spb < bn[vslots // spb]).all(), \
        f"valid slot past block_next{tag}"

    # -- free-pool bookkeeping --
    assert int(s.free_count) == int((bs == FREE).sum()), \
        f"free_count {int(s.free_count)} != recount {int((bs == FREE).sum())}{tag}"
    hint = np.asarray(s.free_hint)
    assert ((hint >= -1) & (hint < B)).all(), f"free_hint range{tag}"
    live = hint >= 0
    assert (hint[live] % cfg.n_dies == np.arange(cfg.n_dies)[live]).all(), \
        f"free_hint off its die{tag}"

    # -- allocation cursors --
    for name, cur in (("open_user", np.asarray(s.open_user)),
                      ("open_mig", np.asarray(s.open_mig))):
        openc = cur >= 0
        assert ((cur >= -1) & (cur < B)).all(), f"{name} range{tag}"
        assert (bs[cur[openc]] == OPEN).all(), f"{name} -> non-OPEN block{tag}"
    om = np.asarray(s.open_mig)
    assert (bm[om[om >= 0]] == np.arange(3)[om >= 0]).all(), \
        f"open_mig block mode mismatch{tag}"


def usable_capacity_pages(state: SSDState, cfg: geometry.SimConfig, xp=jnp):
    """Usable capacity in pages: non-free blocks count at their current
    mode's page count; free blocks count at QLC density (they can be opened
    in any mode, so their capacity potential is the dense one).

    ``xp=numpy`` computes on the host (``pages_per_block_host`` rounds
    identically) so ``engine.summarize`` can run on device_get'ed numpy
    leaves without enqueueing device work (DESIGN.md §7.3); the default
    stays traceable for the in-jit ChunkMetrics use."""
    ppb = (geometry.pages_per_block(cfg) if xp is jnp
           else geometry.pages_per_block_host(cfg))
    per_block = xp.where(
        state.block_state == FREE,
        ppb[modes.QLC],
        ppb[state.block_mode],
    )
    # retired blocks (erase failure, DESIGN.md §2D) left service for good
    per_block = xp.where(state.block_state == BAD, 0, per_block)
    return per_block.sum()


def capacity_gib(state: SSDState, cfg: geometry.SimConfig, xp=jnp):
    # float cast first: pages * page_bytes overflows int32 at real geometry
    return (usable_capacity_pages(state, cfg, xp).astype(xp.float32)
            * cfg.page_bytes / 2**30)
