"""Tail-latency telemetry (DESIGN.md §7.1).

Read retries hurt the *tail* of the read-latency distribution far more than
the mean (Park et al., read-retry optimization; Cai et al., flash error
characterization), so the engine accumulates a fixed log-spaced histogram of
per-read service latency inside the jitted ``lax.scan``. Fixed edges keep
the accumulator a static-shape array (vmap/jit friendly: a batch of runs is
just a stacked ``(R, N_LAT_BINS)`` histogram); log spacing gives ~2% relative
resolution per bin across four decades, which is enough to read off
p50/p95/p99/p999 without storing per-request samples.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Histogram geometry: 16 bins per decade from 8 us to 80 ms. The fastest
# possible read is an SLC sense (20 us); the slowest user read is a QLC page
# at the retry-table cap (140 us * 17 ~= 2.4 ms) plus channel transfer, so
# four decades bracket the achievable range with headroom on both sides.
LAT_MIN_US = 8.0
BINS_PER_DECADE = 16
N_LAT_BINS = 64


def bin_edges_us() -> np.ndarray:
    """(N_LAT_BINS + 1,) log-spaced bin edges in microseconds."""
    exp = np.arange(N_LAT_BINS + 1, dtype=np.float64) / BINS_PER_DECADE
    return LAT_MIN_US * 10.0**exp


def latency_bin(lat_us):
    """Bin index for a latency in microseconds (traced-safe, clipped)."""
    lat = jnp.maximum(jnp.asarray(lat_us, jnp.float32), LAT_MIN_US)
    idx = jnp.floor(jnp.log10(lat / LAT_MIN_US) * BINS_PER_DECADE)
    return jnp.clip(idx.astype(jnp.int32), 0, N_LAT_BINS - 1)


def record(hist, lat_us, mask):
    """Scatter the masked latencies into ``hist`` ((N_LAT_BINS,) f32).

    Runs inside the engine's scan body; masked-out lanes are dropped via an
    out-of-range index (the repo-wide scatter discipline).
    """
    idx = jnp.where(mask, latency_bin(lat_us), N_LAT_BINS)
    return hist.at[idx].add(1.0, mode="drop")


def quantile_bin(hist, q: float) -> int:
    """Index of the bin containing quantile ``q`` of a count histogram.

    Shared bin geometry for the observability layer (DESIGN.md §7.4): the
    latency-attribution tail readout sums component mass over bins at and
    above a mode's q-bin, so it must select bins exactly the way
    :func:`percentiles` does — same ``searchsorted`` + empty-bin advance.
    Returns 0 for an empty histogram.
    """
    h = np.asarray(hist, np.float64)
    total = h.sum()
    if total <= 0:
        return 0
    cum = np.cumsum(h)
    b = int(np.searchsorted(cum, q * total, side="left"))
    nonempty = np.nonzero(h > 0)[0]
    if b >= N_LAT_BINS or h[b] <= 0:
        later = nonempty[nonempty > b] if b < N_LAT_BINS else nonempty[:0]
        b = int(later[0]) if len(later) else int(nonempty[-1])
    return b


def percentiles(hist, qs=(0.5, 0.95, 0.99, 0.999)) -> dict[float, float]:
    """Extract latency quantiles (us) from a histogram by log interpolation.

    ``hist`` is a (N_LAT_BINS,) count array (any float/int dtype, host or
    device). Within the selected bin the quantile position interpolates
    geometrically between the bin edges; an empty histogram returns 0.0.
    """
    h = np.asarray(hist, np.float64)
    total = h.sum()
    edges = bin_edges_us()
    out = {}
    if total <= 0:
        return {q: 0.0 for q in qs}
    cum = np.cumsum(h)
    nonempty = np.nonzero(h > 0)[0]
    for q in qs:
        target = q * total
        b = int(np.searchsorted(cum, target, side="left"))
        # ``searchsorted`` can land on an empty bin when the target count
        # falls exactly on a cumulative boundary, or past the last bin when
        # ``q * total`` exceeds ``cum[-1]`` by a rounding error (np.sum is
        # pairwise, np.cumsum sequential). Interpolating inside a zero-count
        # bin via the eps guard would return that bin's upper edge — a
        # latency no sample ever had — so advance to the next non-empty bin
        # (clamped to the last non-empty one).
        if b >= N_LAT_BINS or h[b] <= 0:
            later = nonempty[nonempty > b] if b < N_LAT_BINS else nonempty[:0]
            b = int(later[0]) if len(later) else int(nonempty[-1])
        prev = cum[b - 1] if b > 0 else 0.0
        frac = (target - prev) / h[b]
        frac = min(max(frac, 0.0), 1.0)
        lo, hi = edges[b], edges[b + 1]
        out[q] = float(lo * (hi / lo) ** frac)
    return out
