"""Chrome trace-event export for the observability layer (DESIGN.md §7.4).

Converts the in-scan instruments (:mod:`repro.ssdsim.obs`) into the Chrome
trace-event JSON format, loadable in Perfetto (ui.perfetto.dev) or
``chrome://tracing``:

- **pid 1 "flash events"** — the resource lattice (DESIGN.md §2C): one
  thread track per die (``die D (chan C)``), one per channel bus
  (``channel C bus``), and a "policy (page-granular)" track. Every decoded
  ring-buffer event becomes a complete ("X") slice named by its trigger
  reason on its block's die track, placed at the event's simulated time
  with a duration *estimated* from the timing-model constants (valid pages
  moved x (read at the event's Eq.-3 retry estimate + program in the
  destination mode), + erase for block-granular relocations); each
  block-granular relocation also drops a companion ``transfer`` slice on
  its die's channel-bus track (pages x ``cfg.transfer_us``), so Perfetto
  shows bus occupancy stacking up under contention. Durations are a
  reconstruction for visual scale — the engine books the exact same
  constants into ``die_busy_ms``/``chan_avail_ms`` but does not retain
  per-event spans.
- **pid 2 "telemetry"** — one counter ("C") track per windowed time series
  (reads, retries, conversions, ...), sampled at each window start.

Everything here is host-side numpy over decoded leaves, so it works on
single runs and on per-run slices of a stacked sweep state alike.
"""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from repro.core import modes
from repro.ssdsim import geometry, obs

PID_FLASH = 1
PID_TELEMETRY = 2

_READ_US = np.asarray(modes.READ_LATENCY_US, np.float64)
_WRITE_US = np.asarray(modes.WRITE_LATENCY_US, np.float64)
_ERASE_US = np.asarray(modes.ERASE_LATENCY_US, np.float64)


def _event_duration_us(rec: dict) -> float:
    """Reconstruct a relocation's device time from the model constants."""
    frm = min(max(rec["from_mode"], 0), modes.N_MODES - 1)
    to = min(max(rec["to_mode"], 0), modes.N_MODES - 1)
    pages = max(rec["pages"], 0)
    per_page = _READ_US[frm] * (1.0 + max(rec["retry_est"], 0.0)) + _WRITE_US[to]
    dur = pages * per_page
    if rec["block"] >= 0:  # block-granular ops erase the source block
        dur += _ERASE_US[frm]
    return float(max(dur, 1.0))  # keep zero-page events visible


def policy_tid(cfg: geometry.SimConfig) -> int:
    """tid of the page-granular policy track (after dies and channel buses)."""
    return cfg.n_dies + cfg.n_channels


def _metadata(cfg: geometry.SimConfig) -> list[dict]:
    md = [
        dict(ph="M", pid=PID_FLASH, tid=0, name="process_name",
             args={"name": "flash events"}),
        dict(ph="M", pid=PID_TELEMETRY, tid=0, name="process_name",
             args={"name": "telemetry"}),
    ]
    # tid layout mirrors the resource lattice: dies first, then one bus
    # track per channel, then the policy track
    for die in range(cfg.n_dies):
        md.append(dict(ph="M", pid=PID_FLASH, tid=die, name="thread_name",
                       args={"name": f"die {die} (chan {cfg.channel_of_die(die)})"}))
    for chan in range(cfg.n_channels):
        md.append(dict(ph="M", pid=PID_FLASH, tid=cfg.n_dies + chan,
                       name="thread_name",
                       args={"name": f"channel {chan} bus"}))
    md.append(dict(ph="M", pid=PID_FLASH, tid=policy_tid(cfg),
                   name="thread_name",
                   args={"name": "policy (page-granular)"}))
    return md


def chrome_trace(s, cfg: geometry.SimConfig) -> dict:
    """Build the trace document (``{"traceEvents": [...], ...}``)."""
    events = _metadata(cfg)
    body: list[dict] = []

    records, total, dropped = obs.decode_events(s, cfg)
    for rec in records:
        # block-granular events pin to their block's die; page-granular
        # conversions (block == -1) span dies and get the policy track
        block_granular = rec["block"] >= 0
        if block_granular:
            die = int(cfg.die_of_block(rec["block"]))
            tid = die
        else:
            tid = policy_tid(cfg)
        args = dict(
            block=rec["block"],
            from_mode=rec["from_mode_name"],
            to_mode=rec["to_mode_name"],
            pages=rec["pages"],
            retry_est=round(rec["retry_est"], 4),
            conversions=rec["conversions"],
        )
        body.append(
            dict(
                ph="X",
                pid=PID_FLASH,
                tid=int(tid),
                ts=rec["t_ms"] * 1000.0,  # trace ts unit is microseconds
                dur=_event_duration_us(rec),
                name=rec["reason_name"],
                cat="relocation",
                args=args,
            )
        )
        if block_granular and rec["pages"] > 0:
            # companion bus slice: the relocated pages' transfers serialize
            # on the die's channel — visual only, like the die-slice spans
            body.append(
                dict(
                    ph="X",
                    pid=PID_FLASH,
                    tid=cfg.n_dies + int(cfg.channel_of_die(die)),
                    ts=rec["t_ms"] * 1000.0,
                    dur=float(max(rec["pages"] * cfg.transfer_us, 1.0)),
                    name="transfer",
                    cat="transfer",
                    args=dict(block=rec["block"], pages=rec["pages"],
                              reason=rec["reason_name"]),
                )
            )

    ts = obs.decode_timeseries(s, cfg)
    win_ms = np.asarray(ts.get("window_start_ms", np.zeros(0)))
    for name in obs.SERIES_NAMES:
        col = np.asarray(ts.get(name, np.zeros(0)))
        for w in range(len(col)):
            if col[w] == 0 and not (w and col[w - 1]):
                continue  # skip leading/inner all-zero stretches
            body.append(
                dict(
                    ph="C",
                    pid=PID_TELEMETRY,
                    tid=0,
                    ts=float(win_ms[w]) * 1000.0,
                    name=name,
                    args={name: float(col[w])},
                )
            )

    body.sort(key=lambda e: e["ts"])
    return dict(
        traceEvents=events + body,
        displayTimeUnit="ms",
        otherData=dict(
            obs_level=cfg.obs_level,
            events_total=total,
            events_dropped=dropped,
            window_ms=float(cfg.obs_window_ms),
        ),
    )


def write_chrome_trace(s, cfg: geometry.SimConfig, path) -> Path:
    """Serialize :func:`chrome_trace` to ``path``; returns the path."""
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(chrome_trace(s, cfg)))
    return p
