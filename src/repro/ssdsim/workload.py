"""FIO-like workload generation (paper §V-A): Zipf-distributed random reads
over an 8 GiB dataset, plus sequential and mixed read/write traces for the
motivation figures. Host-side numpy; the engine consumes padded
(n_chunks, chunk) arrays.

Open-loop arrivals: every builder can attach per-request arrival timestamps
(``arrival_rate`` in IOPS, Poisson or constant-rate interarrivals). A trace
carrying an ``"arrival_ms"`` array drives the engine's queueing-aware
service loop; ``arrival_rate=None`` (the default) keeps the classic
closed-loop trace, where requests are serviced back-to-back.
"""

from __future__ import annotations

import numpy as np

from repro.ssdsim import geometry
from repro.ssdsim.engine import OP_READ, OP_WRITE


def _pack(cfg: geometry.SimConfig, lpn: np.ndarray, op: np.ndarray,
          arrival_ms: np.ndarray | None = None):
    c = cfg.chunk
    n = len(lpn)
    n_chunks = -(-n // c)
    pad = n_chunks * c - n
    lpn = np.concatenate([lpn, np.full(pad, -1, np.int32)])
    op = np.concatenate([op, np.full(pad, OP_READ, np.int32)])
    tr = {
        "lpn": lpn.reshape(n_chunks, c).astype(np.int32),
        "op": op.reshape(n_chunks, c).astype(np.int32),
    }
    if arrival_ms is not None:
        # padding lanes inherit the last real arrival so the chunk's clock
        # never jumps past the payload
        last = arrival_ms[-1] if n else 0.0
        arr = np.concatenate([arrival_ms, np.full(pad, last, np.float64)])
        tr["arrival_ms"] = arr.reshape(n_chunks, c).astype(np.float32)
    return tr


def poisson_arrival_ms(n_requests: int, rate_iops: float, seed: int = 0) -> np.ndarray:
    """Poisson-process arrival timestamps (ms): exponential interarrivals at
    ``rate_iops`` requests/second, starting from t=0."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1000.0 / rate_iops, size=n_requests)
    t = np.cumsum(gaps)
    return t - gaps[0] if n_requests else t


def constant_arrival_ms(n_requests: int, rate_iops: float) -> np.ndarray:
    """Constant-rate arrival timestamps (ms) at ``rate_iops`` requests/s."""
    return np.arange(n_requests, dtype=np.float64) * (1000.0 / rate_iops)


def build_arrivals(n_requests: int, rate_iops: float, dist: str = "poisson",
                   seed: int = 0) -> np.ndarray:
    if dist == "poisson":
        return poisson_arrival_ms(n_requests, rate_iops, seed=seed)
    if dist == "constant":
        return constant_arrival_ms(n_requests, rate_iops)
    raise ValueError(f"unknown arrival distribution {dist!r}")


def attach_arrivals(cfg: geometry.SimConfig, trace: dict, rate_iops: float,
                    dist: str = "poisson", seed: int = 0) -> dict:
    """Attach open-loop arrival timestamps to an already-packed trace.

    Works on any engine trace (scenario library, MSR replay with the
    timestamp column stripped, ...); the arrival stream covers every lane
    including padding, which is harmless since padded lanes are inactive.
    """
    n = trace["lpn"].size
    arr = build_arrivals(n, rate_iops, dist=dist, seed=seed)
    out = dict(trace)
    out["arrival_ms"] = arr.reshape(trace["lpn"].shape).astype(np.float32)
    return out


def zipf_probs(n: int, theta: float) -> np.ndarray:
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks**-theta
    return w / w.sum()


def zipf_read_trace(cfg: geometry.SimConfig, n_requests: int, theta: float,
                    seed: int = 0, hot_fraction_cap: float = 1.0,
                    arrival_rate: float | None = None,
                    arrival_dist: str = "poisson"):
    """Random reads with Zipf(theta) popularity. Hot ranks are scattered
    over the logical space by a fixed permutation (FIO's zipf behaves the
    same way: popularity rank is decoupled from LBA locality)."""
    rng = np.random.default_rng(seed)
    L = cfg.n_logical
    n_ranked = max(int(L * hot_fraction_cap), 1)
    p = zipf_probs(n_ranked, theta)
    ranks = rng.choice(n_ranked, size=n_requests, p=p)
    perm = rng.permutation(L)[:n_ranked]
    lpn = perm[ranks].astype(np.int32)
    arr = None if arrival_rate is None else build_arrivals(
        n_requests, arrival_rate, dist=arrival_dist, seed=seed)
    return _pack(cfg, lpn, np.full(n_requests, OP_READ, np.int32), arr)


def seq_read_trace(cfg: geometry.SimConfig, n_requests: int, start: int = 0):
    lpn = (start + np.arange(n_requests)) % cfg.n_logical
    return _pack(cfg, lpn.astype(np.int32), np.full(n_requests, OP_READ, np.int32))


def uniform_read_trace(cfg: geometry.SimConfig, n_requests: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    lpn = rng.integers(0, cfg.n_logical, size=n_requests).astype(np.int32)
    return _pack(cfg, lpn, np.full(n_requests, OP_READ, np.int32))


def mixed_trace(cfg: geometry.SimConfig, n_requests: int, theta: float,
                read_frac: float = 0.7, seed: int = 0,
                arrival_rate: float | None = None,
                arrival_dist: str = "poisson",
                write_theta: float | None = None):
    """Zipf reads interleaved with random overwrites (paper §V-A).

    Reads follow Zipf(theta) popularity over a fixed permutation; write
    targets default to uniform over the whole logical space, independent of
    the read popularity ranking. ``write_theta`` opts into Zipf-skewed
    writes over an independent permutation instead — hot pages are
    overwritten repeatedly, concentrating invalid pages in recently written
    blocks, which is the workload shape that produces worthwhile GC victims
    (the ``gc_pressure`` benchmark section uses this).
    """
    rng = np.random.default_rng(seed)
    L = cfg.n_logical
    p = zipf_probs(L, theta)
    ranks = rng.choice(L, size=n_requests, p=p)
    perm = rng.permutation(L)
    r_lpn = perm[ranks]
    if write_theta is None:
        w_lpn = rng.integers(0, L, size=n_requests)
    else:
        w_ranks = rng.choice(L, size=n_requests, p=zipf_probs(L, write_theta))
        w_lpn = rng.permutation(L)[w_ranks]
    is_read = rng.random(n_requests) < read_frac
    lpn = np.where(is_read, r_lpn, w_lpn).astype(np.int32)
    op = np.where(is_read, OP_READ, OP_WRITE).astype(np.int32)
    arr = None if arrival_rate is None else build_arrivals(
        n_requests, arrival_rate, dist=arrival_dist, seed=seed)
    return _pack(cfg, lpn, op, arr)
