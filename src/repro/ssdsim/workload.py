"""FIO-like workload generation (paper §V-A): Zipf-distributed random reads
over an 8 GiB dataset, plus sequential and mixed read/write traces for the
motivation figures. Host-side numpy; the engine consumes padded
(n_chunks, chunk) arrays.
"""

from __future__ import annotations

import numpy as np

from repro.ssdsim import geometry
from repro.ssdsim.engine import OP_READ, OP_WRITE


def _pack(cfg: geometry.SimConfig, lpn: np.ndarray, op: np.ndarray):
    c = cfg.chunk
    n = len(lpn)
    n_chunks = -(-n // c)
    pad = n_chunks * c - n
    lpn = np.concatenate([lpn, np.full(pad, -1, np.int32)])
    op = np.concatenate([op, np.full(pad, OP_READ, np.int32)])
    return {
        "lpn": lpn.reshape(n_chunks, c).astype(np.int32),
        "op": op.reshape(n_chunks, c).astype(np.int32),
    }


def zipf_probs(n: int, theta: float) -> np.ndarray:
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks**-theta
    return w / w.sum()


def zipf_read_trace(cfg: geometry.SimConfig, n_requests: int, theta: float,
                    seed: int = 0, hot_fraction_cap: float = 1.0):
    """Random reads with Zipf(theta) popularity. Hot ranks are scattered
    over the logical space by a fixed permutation (FIO's zipf behaves the
    same way: popularity rank is decoupled from LBA locality)."""
    rng = np.random.default_rng(seed)
    L = cfg.n_logical
    n_ranked = max(int(L * hot_fraction_cap), 1)
    p = zipf_probs(n_ranked, theta)
    ranks = rng.choice(n_ranked, size=n_requests, p=p)
    perm = rng.permutation(L)[:n_ranked]
    lpn = perm[ranks].astype(np.int32)
    return _pack(cfg, lpn, np.full(n_requests, OP_READ, np.int32))


def seq_read_trace(cfg: geometry.SimConfig, n_requests: int, start: int = 0):
    lpn = (start + np.arange(n_requests)) % cfg.n_logical
    return _pack(cfg, lpn.astype(np.int32), np.full(n_requests, OP_READ, np.int32))


def uniform_read_trace(cfg: geometry.SimConfig, n_requests: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    lpn = rng.integers(0, cfg.n_logical, size=n_requests).astype(np.int32)
    return _pack(cfg, lpn, np.full(n_requests, OP_READ, np.int32))


def mixed_trace(cfg: geometry.SimConfig, n_requests: int, theta: float,
                read_frac: float = 0.7, seed: int = 0):
    """Zipf reads interleaved with uniform-random overwrites."""
    rng = np.random.default_rng(seed)
    L = cfg.n_logical
    p = zipf_probs(L, theta)
    ranks = rng.choice(L, size=n_requests, p=p)
    perm = rng.permutation(L)
    lpn = perm[ranks].astype(np.int32)
    op = np.where(rng.random(n_requests) < read_frac, OP_READ, OP_WRITE).astype(np.int32)
    return _pack(cfg, lpn, op)
