"""Sharded AdamW with optional ZeRO-style moment sharding over the data
axis, cosine LR schedule, global-norm clipping. Pure jnp; optimizer state
specs mirror the parameter specs so the dry-run stays allocation-free.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.base import ParamSpec, is_spec


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    m: dict
    v: dict
    count: jnp.ndarray


def opt_state_specs(param_specs) -> OptState:
    """Moment specs: same shapes/axes as params, f32 (dry-run abstract)."""
    f32 = jax.tree_util.tree_map(
        lambda s: ParamSpec(s.shape, s.axes, "zeros", jnp.float32),
        param_specs, is_leaf=is_spec,
    )
    return OptState(m=f32, v=f32, count=ParamSpec((), (), "zeros", jnp.int32))


def init(params) -> OptState:
    z = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(m=z, v=jax.tree_util.tree_map(jnp.copy, z), count=jnp.int32(0))


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup) / max(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree_util.tree_leaves(tree))
    )


def update(cfg: AdamWConfig, params, grads, state: OptState):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    count = state.count + 1
    lr = schedule(cfg, count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, td = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.m)
    flat_v = jax.tree_util.tree_leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(td, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(td, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(td, [o[2] for o in out])
    return new_p, OptState(new_m, new_v, count), {"grad_norm": gnorm, "lr": lr}
