"""Training step: loss -> grads -> AdamW, with optional microbatch gradient
accumulation (deferred psum: one gradient reduction per step regardless of
microbatch count — the compute/comm overlap lever) and optional int8
gradient compression with error feedback on the cross-pod axis.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import registry
from repro.training import optim


def make_loss_fn(cfg: ModelConfig):
    api = registry.get_api(cfg)
    return api.loss_fn


def make_train_step(cfg: ModelConfig, ocfg: optim.AdamWConfig,
                    microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With microbatches > 1, the global batch is split along axis 0 and
    gradients are accumulated in a lax.scan — XLA keeps the single psum at
    the end, so DCN/pod traffic is once per step.
    """
    loss_fn = make_loss_fn(cfg)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mb = jax.tree_util.tree_map(split, batch)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def body(acc, mbatch):
                g_acc, l_acc = acc
                loss, grads = jax.value_and_grad(loss_fn)(params, mbatch)
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads
                )
                return (g_acc, l_acc + loss), None

            (grads, loss), _ = lax.scan(body, (zero, jnp.float32(0.0)), mb)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            loss = loss / microbatches

        params, opt_state, metrics = optim.update(ocfg, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step
