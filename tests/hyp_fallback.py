"""Optional-hypothesis shim shared by the property-test modules.

Re-exports the real ``given``/``settings``/``strategies`` when hypothesis is
installed; otherwise substitutes decorators that mark the property tests as
skipped (and a strategy stub so ``@given(x=st.integers(...))`` still
evaluates at import time). The root conftest puts this directory on
``sys.path``.
"""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    def given(*a, **k):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(*a, **k):
        return lambda f: f

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()
