"""Per-architecture smoke tests: a REDUCED config of the same family runs
one forward/train step (and one decode step) on CPU, asserting output shapes
and no NaNs. Full configs are exercised only by the dry-run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_variant
from repro.models import base as B
from repro.models import registry

BATCH, SEQ = 2, 32


def _batch_for(cfg, key):
    ks = jax.random.split(key, 3)
    n_txt = SEQ - cfg.n_img_tokens if cfg.family == "vlm" else SEQ
    batch = {
        "tokens": jax.random.randint(ks[0], (BATCH, n_txt), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (BATCH, n_txt), 0, cfg.vocab),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(ks[2], (BATCH, cfg.enc_len, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(ks[2], (BATCH, cfg.n_img_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_train_step(arch):
    cfg = smoke_variant(ARCHS[arch])
    api = registry.get_api(cfg)
    params = B.materialize(api.specs(), jax.random.PRNGKey(0), jnp.float32)
    batch = _batch_for(cfg, jax.random.PRNGKey(1))

    loss, grads = jax.value_and_grad(api.loss_fn)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    # a sensible xent magnitude for random init
    assert 1.0 < float(loss) < 20.0, f"{arch}: loss {float(loss)}"
    gnorm = jax.tree_util.tree_reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), grads, 0.0
    )
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_decode_step(arch):
    cfg = smoke_variant(ARCHS[arch])
    api = registry.get_api(cfg)
    params = B.materialize(api.specs(), jax.random.PRNGKey(0), jnp.float32)
    batch = _batch_for(cfg, jax.random.PRNGKey(1))
    batch.pop("labels")

    logits, cache = api.prefill(params, batch)
    assert logits.shape[0] == BATCH and logits.shape[1] == 1
    assert np.isfinite(np.array(logits, jnp.float32)).all(), f"{arch}: prefill NaN"

    n_txt = batch["tokens"].shape[1]
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    pos = jnp.full((BATCH,), n_txt, jnp.int32)
    logits2, cache2 = api.decode_step(params, cache, tok, pos)
    assert logits2.shape[:2] == (BATCH, 1)
    assert np.isfinite(np.array(logits2, jnp.float32)).all(), f"{arch}: decode NaN"
    # cache structure is preserved
    jax.tree_util.tree_map(lambda a, b: None, cache, cache2)


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10
    fams = {cfg.family for cfg in ARCHS.values()}
    assert fams == {"dense", "moe", "encdec", "ssm", "vlm", "hybrid"}
