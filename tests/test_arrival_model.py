"""Tests for the open-loop arrival model (DESIGN.md §2C): arrival builders,
the per-LUN Lindley queueing recursion, saturation equivalence with the
closed-loop engine, low/high-load regression behavior, and the
arrival_scale sweep knob."""

import numpy as np
import pytest
from hyp_fallback import given, settings
from hyp_fallback import st as st_h

from repro.experiments import registry, sweep
from repro.ssdsim import engine, geometry, workload
from repro.ssdsim import state as st

TINY = geometry.tiny_config()

# latency telemetry intentionally differs between the models (open-loop
# records queueing-inclusive latency); everything else must agree when the
# open-loop run is saturated from t=0
_TIMING_FIELDS = {"lat_hist", "w_lat_hist", "svc_sum_ms", "q_sum_ms",
                  "chanq_sum_ms", "die_avail_ms", "chan_avail_ms",
                  "clock_ms", "die_busy_ms", "chan_busy_ms",
                  "page_write_ms", "heat", "n_retries"}


def _zero_arrivals(trace):
    out = dict(trace)
    out["arrival_ms"] = np.zeros(trace["lpn"].shape, np.float32)
    return out


class TestArrivalBuilders:
    def test_poisson_monotone_zero_based_mean_gap(self):
        t = workload.poisson_arrival_ms(50_000, rate_iops=10_000.0, seed=3)
        assert t[0] == 0.0
        assert (np.diff(t) >= 0).all()
        gaps = np.diff(t)
        assert abs(gaps.mean() - 0.1) < 0.005  # 10k IOPS -> 0.1 ms mean gap

    def test_constant_rate_exact(self):
        t = workload.constant_arrival_ms(5, rate_iops=1000.0)
        np.testing.assert_allclose(t, [0.0, 1.0, 2.0, 3.0, 4.0])

    def test_unknown_dist_raises(self):
        with pytest.raises(ValueError):
            workload.build_arrivals(10, 100.0, dist="bursty")

    def test_pack_pads_arrivals_with_last(self):
        n = TINY.chunk - 5
        arr = np.arange(n, dtype=np.float64)
        tr = workload._pack(TINY, np.zeros(n, np.int32),
                            np.zeros(n, np.int32), arr)
        flat = tr["arrival_ms"].reshape(-1)
        assert tr["arrival_ms"].dtype == np.float32
        assert (flat[n:] == flat[n - 1]).all()

    def test_attach_arrivals_shape_and_determinism(self):
        tr = workload.zipf_read_trace(TINY, 3_000, 1.2, seed=0)
        a = workload.attach_arrivals(TINY, tr, 5_000.0, seed=7)
        b = workload.attach_arrivals(TINY, tr, 5_000.0, seed=7)
        assert a["arrival_ms"].shape == a["lpn"].shape
        np.testing.assert_array_equal(a["arrival_ms"], b["arrival_ms"])
        assert "arrival_ms" not in tr  # original untouched

    def test_generators_accept_arrival_rate(self):
        for tr in (
            workload.zipf_read_trace(TINY, 2_000, 1.2, seed=0, arrival_rate=1e4),
            workload.mixed_trace(TINY, 2_000, 1.2, seed=0, arrival_rate=1e4,
                                 arrival_dist="constant"),
        ):
            assert "arrival_ms" in tr
            flat = tr["arrival_ms"].reshape(-1)
            assert (np.diff(flat) >= 0).all()


class TestQueueDepartures:
    """Unit tests of the vectorized Lindley recursion against a reference
    per-request simulation."""

    def _reference(self, avail0, arr, svc, lun, active, n_luns):
        avail = np.array(avail0, np.float64)
        dep = np.zeros(len(arr))
        for i in range(len(arr)):
            if not active[i]:
                dep[i] = avail[lun[i]]
                continue
            start = max(arr[i], avail[lun[i]])
            avail[lun[i]] = start + svc[i]
            dep[i] = avail[lun[i]]
        return dep, avail

    @settings(max_examples=20, deadline=None)
    @given(seed=st_h.integers(0, 2**16))
    def test_matches_sequential_reference(self, seed):
        import jax.numpy as jnp

        rng = np.random.default_rng(seed)
        n, n_luns = 64, 4
        arr = np.sort(rng.random(n) * 10.0)
        svc = rng.random(n) * 0.5
        lun = rng.integers(0, n_luns, n)
        active = rng.random(n) < 0.8
        avail0 = rng.random(n_luns) * 2.0
        dep, avail1 = engine._queue_departures(
            jnp.asarray(avail0, jnp.float32), jnp.asarray(arr, jnp.float32),
            jnp.asarray(np.where(active, svc, 0.0), jnp.float32),
            jnp.asarray(lun, jnp.int32), jnp.asarray(active), n_luns,
        )
        ref_dep, ref_avail = self._reference(avail0, arr, svc, lun, active, n_luns)
        np.testing.assert_allclose(
            np.asarray(dep)[active], ref_dep[active], rtol=1e-4, atol=1e-4
        )
        np.testing.assert_allclose(np.asarray(avail1), ref_avail,
                                   rtol=1e-4, atol=1e-4)

    def test_idle_lun_keeps_avail(self):
        import jax.numpy as jnp

        dep, avail1 = engine._queue_departures(
            jnp.asarray([5.0, 7.0], jnp.float32),
            jnp.asarray([0.0, 1.0], jnp.float32),
            jnp.asarray([1.0, 1.0], jnp.float32),
            jnp.asarray([0, 0], jnp.int32),
            jnp.asarray([True, True]), 2,
        )
        # LUN 0 serves back-to-back from its availability clock; LUN 1 idle
        np.testing.assert_allclose(np.asarray(dep), [6.0, 7.0])
        np.testing.assert_allclose(np.asarray(avail1), [7.0, 7.0])


class TestSaturationEquivalence:
    """arrival_rate -> infinity (every arrival at t=0) saturates the device,
    so the open-loop engine must reproduce the closed-loop run exactly:
    identical FTL state and, per LUN, final availability == cumulative busy
    time (service is back-to-back with zero idling)."""

    @settings(max_examples=6, deadline=None)
    @given(
        seed=st_h.integers(0, 2**16),
        pol=st_h.sampled_from([geometry.BASELINE, geometry.RARO]),
    )
    def test_property_saturation_matches_closed_loop(self, seed, pol):
        cfg = geometry.tiny_config(policy=pol, initial_pe=500)
        tr = workload.mixed_trace(cfg, 2_000, 1.2, read_frac=0.8, seed=seed)
        s_c, _ = engine.run(cfg, tr)
        s_o, _ = engine.run(cfg, _zero_arrivals(tr))
        for name, a, b in zip(s_c._fields, s_c, s_o):
            if name in _TIMING_FIELDS:
                continue
            a, b = np.asarray(a), np.asarray(b)
            if a.dtype.kind == "f":
                np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5,
                                           err_msg=name)
            else:
                assert (a == b).all(), name
        # service totals: no idling, so availability == busy time per LUN
        np.testing.assert_allclose(np.asarray(s_o.die_avail_ms),
                                   np.asarray(s_o.die_busy_ms),
                                   rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(np.asarray(s_o.die_busy_ms),
                                   np.asarray(s_c.die_busy_ms),
                                   rtol=1e-4, atol=1e-3)
        assert float(s_o.lat_hist.sum()) == float(s_c.lat_hist.sum())

    def test_single_lun_service_totals_exact(self):
        cfg = geometry.tiny_config(n_channels=1, luns_per_channel=1,
                                   blocks_per_plane=64, policy=geometry.RARO,
                                   initial_pe=500)
        tr = workload.zipf_read_trace(cfg, 3_000, 1.2, seed=1)
        s_c, _ = engine.run(cfg, tr)
        s_o, _ = engine.run(cfg, _zero_arrivals(tr))
        assert float(s_c.n_reads) == float(s_o.n_reads)
        assert float(s_c.n_retries) == float(s_o.n_retries)
        np.testing.assert_allclose(np.asarray(s_o.die_avail_ms),
                                   np.asarray(s_c.die_busy_ms), rtol=1e-5)


class TestLoadRegression:
    def _hammer(self, cfg, rate):
        tr = registry.build("read_disturb_hammer", cfg, 6_000, seed=0)
        return workload.attach_arrivals(cfg, tr, rate, seed=1)

    def test_low_load_has_negligible_queueing(self):
        # ~5 IOPS against ~2.4 ms hammered-QLC reads: utilization ~1%, so
        # queueing is negligible even at the p99
        cfg = geometry.tiny_config(policy=geometry.BASELINE, initial_pe=833)
        s_o, _ = engine.run(cfg, self._hammer(cfg, rate=5.0))
        m = engine.summarize(s_o, cfg)
        # queueing delay is a vanishing fraction of the recorded latency
        assert m["read_queue_delay_us"] < 0.05 * m["mean_read_latency_us"]
        # ... so the read histogram is within a bin of the closed-loop one
        tr = registry.build("read_disturb_hammer", cfg, 6_000, seed=0)
        s_c, _ = engine.run(cfg, tr)
        m_c = engine.summarize(s_c, cfg)
        assert m["read_lat_p99_us"] == pytest.approx(m_c["read_lat_p99_us"],
                                                     rel=0.10)

    def test_high_load_p99_exceeds_closed_loop(self):
        """Acceptance criterion: at high offered load on a retry-heavy trace
        the open-loop p99 strictly exceeds the closed-loop p99 — queueing is
        visible in the histogram."""
        cfg = geometry.tiny_config(policy=geometry.BASELINE, initial_pe=833)
        tr = registry.build("read_disturb_hammer", cfg, 6_000, seed=0)
        s_c, _ = engine.run(cfg, tr)
        m_c = engine.summarize(s_c, cfg)
        s_o, ys = engine.run(cfg, self._hammer(cfg, rate=1e6))
        m_o = engine.summarize(s_o, cfg)
        assert m_o["read_lat_p99_us"] > m_c["read_lat_p99_us"]
        assert m_o["read_queue_delay_us"] > 0
        assert float(np.asarray(ys.q_ms).sum()) == pytest.approx(
            float(s_o.q_sum_ms), rel=1e-5)

    def test_queue_delay_monotone_in_offered_load(self):
        spec = sweep.SweepSpec(
            scenario="hammer_openloop", n_requests=4_000,
            policies=(geometry.BASELINE,), initial_pe=(833,), seeds=(0,),
            arrival_scale=(0.25, 4.0),
            scenario_kw=(("rate_iops", 2_000.0),), base=TINY,
        )
        res = sweep.run_sweep(spec)
        by = {r["run"]["arrival_scale"]: r for r in res}
        assert by[4.0]["read_queue_delay_us"] > by[0.25]["read_queue_delay_us"]
        assert by[4.0]["run"]["tag"].endswith("load4")
        assert by[0.25]["run"]["tag"].endswith("load0.25")

    def test_arrival_scale_warns_on_closed_loop_scenario(self):
        spec = sweep.SweepSpec(
            scenario="read_disturb_hammer", n_requests=1_000,
            policies=(geometry.BASELINE,), initial_pe=(166,), seeds=(0,),
            arrival_scale=(1.0, 2.0), base=TINY,
        )
        with pytest.warns(UserWarning, match="no arrival timestamps"):
            sweep.run_sweep(spec)


@pytest.mark.slow
class TestMG1Sanity:
    """M/G/1 sanity check (ROADMAP open refinement): on a single-LUN device
    with Poisson read arrivals the measured mean queueing delay must match
    the Pollaczek-Khinchine formula  Wq = lambda * E[S^2] / (2 (1 - rho)).

    BASELINE policy + read-only trace keeps the mapping static (no
    migrations/GC/writes), so per-request service times are an iid draw from
    the initial state's per-page retry latencies: S = (1 + retries) * t_QLC.
    ``initial_pe=0`` keeps the retry table flat over the run (asserted via
    retries_per_read == the static expectation), i.e. service is stationary.
    """

    def _setup(self, n=30_000, theta=0.9, seed=5):
        import jax.numpy as jnp

        from repro.core import modes as m_, retry

        cfg = geometry.tiny_config(
            n_channels=1, luns_per_channel=1, blocks_per_plane=64,
            policy=geometry.BASELINE, initial_pe=0,
        )
        lpns = workload.zipf_read_trace(cfg, n, theta, seed=seed)["lpn"].reshape(-1)[:n]
        r = np.asarray(retry.page_retries(
            jnp.int32(m_.QLC), jnp.int32(cfg.initial_pe),
            jnp.float32(cfg.device_age_h), jnp.int32(0),
            jnp.arange(cfg.n_slots, dtype=jnp.int32),
        ))
        svc_ms = (1.0 + r[lpns]) * float(m_.READ_LATENCY_US[m_.QLC]) / 1000.0
        return cfg, r, svc_ms

    @pytest.mark.parametrize("rho_target", [0.4, 0.6, 0.75])
    def test_mean_queue_delay_matches_pollaczek_khinchine(self, rho_target):
        n, theta, seed = 30_000, 0.9, 5
        cfg, r, svc_ms = self._setup(n, theta, seed)
        es, es2 = svc_ms.mean(), (svc_ms**2).mean()
        lam = rho_target / es  # arrivals per ms
        tr = workload.zipf_read_trace(
            cfg, n, theta, seed=seed, arrival_rate=lam * 1000.0
        )
        s, _ = engine.run(cfg, tr)
        m = engine.summarize(s, cfg)
        # stationarity: measured retries equal the static expectation, so
        # the host-side service moments describe the run
        assert m["retries_per_read"] == pytest.approx(
            float(np.mean(r[tr["lpn"].reshape(-1)[:n]])), rel=1e-3
        )
        rho = lam * es
        wq_us = lam * es2 / (2.0 * (1.0 - rho)) * 1000.0
        assert m["read_queue_delay_us"] == pytest.approx(wq_us, rel=0.15)


class TestOpenLoopReplay:
    def test_msr_sample_replays_open_loop(self):
        tr = registry.build("msr_sample", TINY, 2_000, seed=0)
        assert "arrival_ms" in tr
        flat = tr["arrival_ms"].reshape(-1)
        assert (np.diff(flat) >= 0).all()  # cycling keeps time monotone
        s, _ = engine.run(TINY, tr)
        assert float(s.n_reads) + float(s.n_writes) == 2_000
        assert float(s.die_avail_ms.max()) > 0

    def test_msr_sample_closed_loop_opt_out(self):
        tr = registry.build("msr_sample", TINY, 1_000, seed=0, arrivals=False)
        assert "arrival_ms" not in tr
        s, _ = engine.run(TINY, tr)
        assert float(s.die_avail_ms.max()) == 0.0


class TestPolicyDedup:
    """The sort+adjacent-mask dedup (replacing jnp.unique) must migrate each
    chunk-repeated LPN at most once and keep candidates in ascending LPN
    order (the jnp.unique tie-break)."""

    def test_hammered_single_page_keeps_invariants(self):
        cfg = geometry.tiny_config(policy=geometry.RARO, initial_pe=833)
        tr = registry.build("read_disturb_hammer", cfg, 4_000, seed=0,
                            hammer_pages=1, hammer_prob=1.0)
        s, _ = engine.run(cfg, tr)
        # double-migration of the duplicate would corrupt block_valid
        p2l = np.asarray(s.p2l)
        vslots = np.nonzero(p2l >= 0)[0]
        counts = np.bincount(vslots // cfg.slots_per_block,
                             minlength=cfg.n_blocks)
        assert (np.asarray(s.block_valid) == counts).all()
        assert (np.asarray(s.l2p) >= 0).all()

    def test_dedup_matches_jnp_unique_semantics(self):
        """The inline sort+mask must select the same unique set (and -1 the
        rest) as jnp.unique over the masked read LPNs."""
        import jax.numpy as jnp

        rng = np.random.default_rng(4)
        for _ in range(20):
            lpns = rng.integers(0, 64, size=128).astype(np.int32)
            rd = rng.random(128) < 0.7
            srt = jnp.sort(jnp.where(jnp.asarray(rd), jnp.asarray(lpns), 64))
            dup = jnp.concatenate([jnp.zeros((1,), bool), srt[1:] == srt[:-1]])
            uniq = np.asarray(jnp.where((srt >= 64) | dup, -1, srt))
            expect = np.unique(lpns[rd])
            got = np.sort(uniq[uniq >= 0])
            np.testing.assert_array_equal(got, expect)
            # survivors stay ascending in place (tie-break order)
            kept = uniq[uniq >= 0]
            assert (np.diff(kept) > 0).all()

    def test_policy_still_migrates(self):
        cfg = geometry.tiny_config(policy=geometry.RARO, initial_pe=500)
        tr = workload.zipf_read_trace(cfg, 2_000, 1.4, seed=2)
        s, _ = engine.run(cfg, tr)
        assert float(s.n_migrated_pages) > 0  # dedup didn't kill the policy
