"""Unit tests for the CI benchmark regression gate
(benchmarks/check_regression.py): it must demonstrably fail on a large
artificial slowdown, pass on the real baseline, and never silently compare
nothing."""

import json

import pytest

from benchmarks.check_regression import gate, main, render_markdown


def _doc(read_only=4_900.0, mixed=3_340.0):
    return {
        "bench": "engine",
        "rows": [
            ["engine/read_only/compile_s", 4.0, "s"],
            ["engine/read_only/chunks_per_sec", read_only, "chunks/s"],
            ["engine/mixed/chunks_per_sec", mixed, "chunks/s"],
        ],
    }


class TestGateFunction:
    def test_passes_on_baseline(self):
        entries = gate(_doc(), _doc())
        assert [e[4] for e in entries] == ["OK", "OK"]
        assert all(e[3] == 1.0 for e in entries)

    def test_fails_on_10x_slowdown(self):
        entries = gate(_doc(read_only=490.0, mixed=334.0), _doc())
        assert [e[4] for e in entries] == ["FAIL", "FAIL"]

    def test_warn_band_does_not_fail(self):
        # 0.7x: inside [fail_below, warn_below) -> WARN, and main() exits 0
        entries = gate(_doc(read_only=4_900 * 0.7, mixed=3_340 * 0.7), _doc())
        assert [e[4] for e in entries] == ["WARN", "WARN"]

    def test_speedups_are_ok(self):
        entries = gate(_doc(read_only=49_000.0, mixed=33_400.0), _doc())
        assert [e[4] for e in entries] == ["OK", "OK"]

    def test_no_common_rows_raises(self):
        with pytest.raises(ValueError, match="no common rows"):
            gate(_doc(), {"rows": [["other/metric/chunks_per_sec2", 1.0, "x"]]})

    def test_vanished_measured_row_raises(self):
        # a guarded section dropping out of the fresh artifact must not pass
        measured = _doc()
        measured["rows"] = [r for r in measured["rows"] if "mixed" not in r[0]]
        with pytest.raises(ValueError, match="missing from the measured"):
            gate(measured, _doc())

    def test_new_measured_rows_without_baseline_ok(self):
        # the reverse is fine: new metrics may not have a baseline yet
        measured = _doc()
        measured["rows"].append(["engine/new_path/chunks_per_sec", 9.9, "chunks/s"])
        entries = gate(measured, _doc())
        assert [e[4] for e in entries] == ["OK", "OK"]

    def test_only_suffix_rows_compared(self):
        entries = gate(_doc(), _doc())
        names = [e[0] for e in entries]
        assert all(n.endswith("/chunks_per_sec") for n in names)
        assert not any("compile_s" in n for n in names)

    def test_require_pins_guarded_set(self):
        """--require names a metric that must exist in both artifacts —
        the gc_pressure section cannot silently drop out of the gate."""
        req = ("engine/gc_pressure/chunks_per_sec",)
        with pytest.raises(ValueError, match="required metric"):
            gate(_doc(), _doc(), require=req)
        withgc = _doc()
        withgc["rows"].append(
            ["engine/gc_pressure/chunks_per_sec", 100.0, "chunks/s"])
        entries = gate(withgc, withgc, require=req)
        assert [e[4] for e in entries] == ["OK", "OK", "OK"]


class TestGateMain:
    def _write(self, tmp_path, name, doc):
        p = tmp_path / name
        p.write_text(json.dumps(doc))
        return str(p)

    def test_exit_codes(self, tmp_path, monkeypatch):
        monkeypatch.delenv("GITHUB_STEP_SUMMARY", raising=False)
        base = self._write(tmp_path, "base.json", _doc())
        good = self._write(tmp_path, "good.json", _doc())
        slow = self._write(
            tmp_path, "slow.json", _doc(read_only=490.0, mixed=334.0)
        )
        assert main(["--measured", good, "--baseline", base]) == 0
        assert main(["--measured", slow, "--baseline", base]) == 1

    def test_baseline_key_selects_subdoc(self, tmp_path, monkeypatch):
        monkeypatch.delenv("GITHUB_STEP_SUMMARY", raising=False)
        # top-level rows are a different (full) geometry: comparing against
        # them would fail; the tiny_baseline sub-doc must be used instead
        base = self._write(
            tmp_path, "base.json",
            {"rows": _doc(read_only=490.0, mixed=334.0)["rows"],
             "tiny_baseline": _doc()},
        )
        measured = self._write(tmp_path, "m.json", _doc())
        assert main(["--measured", measured, "--baseline", base,
                     "--baseline-key", "tiny_baseline"]) == 0
        assert main(["--measured", measured, "--baseline", base,
                     "--baseline-key", "missing"]) == 2

    def test_summary_table_written(self, tmp_path, monkeypatch):
        monkeypatch.delenv("GITHUB_STEP_SUMMARY", raising=False)
        base = self._write(tmp_path, "base.json", _doc())
        slow = self._write(
            tmp_path, "slow.json", _doc(read_only=490.0, mixed=3_340.0)
        )
        summary = tmp_path / "summary.md"
        main(["--measured", slow, "--baseline", base,
              "--summary", str(summary)])
        text = summary.read_text()
        assert "engine/read_only/chunks_per_sec" in text
        assert "FAIL" in text and "OK" in text

    def test_committed_baseline_has_tiny_key(self):
        """The CI gate command points at benchmarks/BENCH_engine.json with
        --baseline-key tiny_baseline; that key must exist and carry
        chunks/s rows, or the gate dies at runtime."""
        from pathlib import Path

        doc = json.loads(
            (Path(__file__).parent.parent / "benchmarks" /
             "BENCH_engine.json").read_text()
        )
        rows = doc["tiny_baseline"]["rows"]
        assert doc["tiny_baseline"]["config"]["tiny"] is True
        names = [r[0] for r in rows if r[0].endswith("/chunks_per_sec")]
        assert len(names) == 8
        # the guarded set includes the fused-GC pressure section, the
        # armed fault-injection path, the lattice channel model, the
        # lifespan GC scorer, and the wear-correlated fault path
        assert "engine/gc_pressure/chunks_per_sec" in names
        assert "engine/mixed_faults/chunks_per_sec" in names
        assert "engine/channel_contention/chunks_per_sec" in names
        assert "engine/gc_lifespan/chunks_per_sec" in names
        assert "engine/wearout/chunks_per_sec" in names

    def test_markdown_render(self):
        md = render_markdown(gate(_doc(), _doc()), 0.5, 0.8)
        assert md.count("|") > 8 and "ratio" in md
