"""Channel/die/plane timing-lattice tests (DESIGN.md §2C).

Covers the two-resource tandem Lindley recursion against a sequential
per-request reference, the pinned bit-identity of ``chan_model="legacy"``
and of the degenerate lattice (one die per channel, infinite channel
bandwidth), the M/G/1-style sanity that dies funneling into one channel
saturate at channel bandwidth, the multi-plane background-work overlap
charges, and the faults entity re-keying.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hyp_fallback import given, settings
from hyp_fallback import st as st_h

from repro.core import faults as flt
from repro.core import modes, retry
from repro.ssdsim import engine, ftl, geometry, workload
from repro.ssdsim import state as st


def _state_identical(sa, sb, exclude=("chan_avail_ms",)):
    """Assert two engine states are bitwise identical, minus ``exclude``.

    ``chan_avail_ms`` is excluded by default: the degenerate lattice still
    tracks the arrival cummax through the (zero-occupancy) channel pass,
    while legacy leaves the clock at 0 — the only tolerated divergence.
    """
    for name, a, b in zip(sa._fields, sa, sb):
        if name in exclude:
            continue
        a, b = np.asarray(a), np.asarray(b)
        assert a.shape == b.shape, name
        assert (a == b).all(), name


class TestLatticeIndexing:
    def test_block_to_die_plane_roundtrip(self):
        cfg = geometry.tiny_config(planes_per_lun=2)
        blk = np.arange(cfg.n_blocks)
        die = np.asarray(cfg.die_of_block(blk))
        plane = np.asarray(cfg.plane_of_block(blk))
        assert die.min() == 0 and die.max() == cfg.n_dies - 1
        assert plane.min() == 0 and plane.max() == cfg.planes_per_die - 1
        # die-first striping: consecutive blocks land on consecutive dies,
        # identical to the historical blk % n_luns
        np.testing.assert_array_equal(die, blk % cfg.n_luns)
        # every (die, plane) pair holds exactly blocks_per_plane blocks
        slot = np.asarray(cfg.plane_slot_of_block(blk))
        counts = np.bincount(slot, minlength=cfg.n_dies * cfg.planes_per_die)
        assert (counts == cfg.blocks_per_plane).all()

    def test_channel_of_die_stripes(self):
        cfg = geometry.tiny_config()
        chans = [cfg.channel_of_die(d) for d in range(cfg.n_dies)]
        assert set(chans) == set(range(cfg.n_channels))

    def test_invalid_chan_model_rejected(self):
        with pytest.raises(ValueError, match="chan_model"):
            geometry.tiny_config(chan_model="queueless")


class TestTandemDepartures:
    """The vectorized two-resource recursion against a sequential
    per-request tandem simulation (the analog of PR 5's
    ``TestQueueDepartures``)."""

    def _reference(self, die_avail0, chan_avail0, arr, die_occ, xfer, die,
                   chan, rd, active):
        die_avail = np.array(die_avail0, np.float64)
        chan_avail = np.array(chan_avail0, np.float64)
        n = len(arr)
        die_dep = np.zeros(n)
        chan_dep = np.zeros(n)
        for i in range(n):
            if not active[i]:
                die_dep[i] = die_avail[die[i]]
                chan_dep[i] = chan_avail[chan[i]]
                continue
            start = max(arr[i], die_avail[die[i]])
            die_avail[die[i]] = start + die_occ[i]
            die_dep[i] = die_avail[die[i]]
            # transfer eligible at sense end for reads, at arrival for writes
            t_arr = die_dep[i] if rd[i] else arr[i]
            cstart = max(t_arr, chan_avail[chan[i]])
            chan_avail[chan[i]] = cstart + xfer[i]
            chan_dep[i] = chan_avail[chan[i]]
        return die_dep, chan_dep, die_avail, chan_avail

    @settings(max_examples=20, deadline=None)
    @given(seed=st_h.integers(0, 2**16))
    def test_matches_sequential_reference(self, seed):
        rng = np.random.default_rng(seed)
        n, n_dies, n_channels = 64, 4, 2
        arr = np.sort(rng.random(n) * 10.0)
        occ = rng.random(n) * 0.5
        xfer = rng.random(n) * 0.1
        die = rng.integers(0, n_dies, n)
        chan = die % n_channels
        active = rng.random(n) < 0.8
        rd = rng.random(n) < 0.7
        die_avail0 = rng.random(n_dies) * 2.0
        chan_avail0 = rng.random(n_channels) * 2.0
        dd, cd, da, ca = engine._tandem_departures(
            jnp.asarray(die_avail0, jnp.float32),
            jnp.asarray(chan_avail0, jnp.float32),
            jnp.asarray(arr, jnp.float32),
            jnp.asarray(np.where(active, occ, 0.0), jnp.float32),
            jnp.asarray(np.where(active, xfer, 0.0), jnp.float32),
            jnp.asarray(die, jnp.int32), jnp.asarray(chan, jnp.int32),
            jnp.asarray(rd), jnp.asarray(active), n_dies, n_channels,
        )
        rdd, rcd, rda, rca = self._reference(
            die_avail0, chan_avail0, arr, occ, xfer, die, chan, rd, active
        )
        np.testing.assert_allclose(np.asarray(dd)[active], rdd[active],
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(cd)[active], rcd[active],
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(da), rda, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(ca), rca, rtol=1e-4, atol=1e-4)

    def test_infinite_bandwidth_collapses_to_die_pass(self):
        """Zero transfer time: channel departures coincide with die
        departures when each die owns its channel."""
        n_dies = 2
        arr = jnp.asarray([0.0, 0.1, 0.2, 0.3], jnp.float32)
        occ = jnp.asarray([0.5, 0.5, 0.5, 0.5], jnp.float32)
        die = jnp.asarray([0, 1, 0, 1], jnp.int32)
        active = jnp.asarray([True] * 4)
        dd, cd, da, ca = engine._tandem_departures(
            jnp.zeros(n_dies), jnp.zeros(n_dies), arr, occ,
            jnp.zeros(4, jnp.float32), die, die, jnp.asarray([True] * 4),
            active, n_dies, n_dies,
        )
        np.testing.assert_array_equal(np.asarray(dd), np.asarray(cd))
        np.testing.assert_array_equal(np.asarray(da), np.asarray(ca))


class TestLegacyIdentity:
    """The pinned reachability of the old scheduler: legacy mode is the
    default, and the degenerate lattice (1 die/channel, infinite channel
    bandwidth) reproduces it bit for bit on real engine runs."""

    def _traces(self, cfg, seed, rate=None):
        return workload.mixed_trace(
            cfg, 8 * cfg.chunk, theta=1.0, read_frac=0.7, seed=seed,
            arrival_rate=rate,
        )

    @settings(max_examples=4, deadline=None)
    @given(
        seed=st_h.integers(0, 2**16),
        pol=st_h.sampled_from([geometry.BASELINE, geometry.RARO]),
    )
    def test_degenerate_lattice_open_loop_bit_identical(self, seed, pol):
        cfg = geometry.tiny_config(
            n_channels=4, luns_per_channel=1, channel_mb_s=float("inf"),
            policy=pol, initial_pe=500,
        )
        tr = self._traces(cfg, seed, rate=30000.0)
        s_legacy, m_legacy = engine.run(cfg, tr)
        s_lat, m_lat = engine.run(
            dataclasses.replace(cfg, chan_model="lattice"), tr
        )
        _state_identical(s_legacy, s_lat)
        np.testing.assert_array_equal(np.asarray(m_legacy.lat_hist),
                                      np.asarray(m_lat.lat_hist))
        assert float(s_lat.chanq_sum_ms) == 0.0

    def test_degenerate_lattice_closed_loop_bit_identical(self):
        cfg = geometry.tiny_config(
            n_channels=4, luns_per_channel=1, channel_mb_s=float("inf"),
            policy=geometry.RARO, initial_pe=500,
        )
        tr = self._traces(cfg, seed=7)
        s_legacy, _ = engine.run(cfg, tr)
        s_lat, _ = engine.run(
            dataclasses.replace(cfg, chan_model="lattice"), tr
        )
        _state_identical(s_legacy, s_lat)

    def test_lattice_noop_on_closed_loop_any_geometry(self):
        """The closed-loop path traces no queueing code, so legacy and
        lattice agree bitwise even at contended geometry (1 plane)."""
        cfg = geometry.tiny_config(policy=geometry.RARO, initial_pe=500)
        tr = self._traces(cfg, seed=3)
        s_legacy, _ = engine.run(cfg, tr)
        s_lat, _ = engine.run(
            dataclasses.replace(cfg, chan_model="lattice"), tr
        )
        _state_identical(s_legacy, s_lat)

    def test_contended_lattice_actually_diverges(self):
        """Non-vacuity: at finite bandwidth with dies sharing a channel the
        lattice must differ from legacy (transfer queueing exists)."""
        cfg = geometry.tiny_config(policy=geometry.BASELINE, initial_pe=500)
        tr = self._traces(cfg, seed=3, rate=30000.0)
        s_legacy, _ = engine.run(cfg, tr)
        s_lat, _ = engine.run(
            dataclasses.replace(cfg, chan_model="lattice"), tr
        )
        assert float(s_lat.chanq_sum_ms) > 0.0
        assert not np.array_equal(np.asarray(s_legacy.lat_hist),
                                  np.asarray(s_lat.lat_hist))


class TestChannelSaturation:
    """M/G/1-style sanity (the analog of PR 5's ``TestMG1Sanity``): with a
    transfer-dominated channel, 2 dies funneling into 1 bus saturate at
    channel bandwidth, not at 2x die bandwidth."""

    def _run(self, mb_s, rate_iops, n=20_000):
        cfg = geometry.tiny_config(
            n_channels=1, luns_per_channel=2, blocks_per_plane=64,
            policy=geometry.BASELINE, initial_pe=0, channel_mb_s=mb_s,
            chan_model="lattice",
        )
        tr = workload.zipf_read_trace(cfg, n, 0.9, seed=5,
                                      arrival_rate=rate_iops)
        s, _ = engine.run(cfg, tr)
        return cfg, s, engine.summarize(s, cfg)

    def test_two_dies_one_channel_saturate_at_channel_bandwidth(self):
        # transfer_us = 16384/40.96 = 400 us per page >> QLC sense, so the
        # bus is the bottleneck: read-disturb retries put per-read die
        # service near (1+1.6)*140 = 368 us, so the 2 dies absorb the
        # 4/ms offered rate (~5.4/ms die capacity) but the 2.5/ms channel
        # cannot — the makespan must converge to n_reads * transfer_us
        # (bus at 100% duty), and the wait lives on the channel, not the
        # dies
        cfg, s, m = self._run(mb_s=40.96, rate_iops=4_000.0)
        n = float(s.n_reads)
        chan_limit_ms = n * cfg.transfer_us / 1000.0
        makespan_ms = float(np.asarray(s.chan_avail_ms).max())
        assert makespan_ms == pytest.approx(chan_limit_ms, rel=0.05)
        # the channel-overload wait dwarfs the (stable) die queueing
        assert m["read_chan_wait_us"] > 10.0 * m["read_queue_delay_us"]

    def test_throughput_tracks_offered_load_below_saturation(self):
        # at ~50% channel utilization the bus never backs up much: mean
        # channel wait stays well under one transfer time
        cfg, s, m = self._run(mb_s=40.96, rate_iops=1_250.0)
        assert m["read_chan_wait_us"] < cfg.transfer_us


class TestChannelContention:
    """Acceptance criterion: a 1-channel/multi-die lattice under offered
    load shows transfer queueing — the measured read p99 strictly exceeds
    the largest possible sense + retry + transfer service sum."""

    def test_p99_exceeds_service_bound_under_load(self):
        cfg = geometry.tiny_config(
            n_channels=1, luns_per_channel=4, blocks_per_plane=32,
            policy=geometry.BASELINE, initial_pe=0, chan_model="lattice",
        )
        # BASELINE + pe=0 keeps the retry table static, so the per-slot
        # service bound is exact: (1 + max retries) * t_QLC + transfer
        r = np.asarray(retry.page_retries(
            jnp.int32(modes.QLC), jnp.int32(cfg.initial_pe),
            jnp.float32(cfg.device_age_h), jnp.int32(0),
            jnp.arange(cfg.n_slots, dtype=jnp.int32),
        ))
        svc_bound_us = (1.0 + r.max()) * float(
            modes.READ_LATENCY_US[modes.QLC]
        ) + cfg.transfer_us
        tr = workload.zipf_read_trace(cfg, 20_000, 0.9, seed=5,
                                      arrival_rate=30_000.0)
        s, _ = engine.run(cfg, tr)
        m = engine.summarize(s, cfg)
        assert m["read_lat_p99_us"] > svc_bound_us
        assert m["read_chan_wait_us"] > 0.0
        # legacy at the same geometry records no transfer queueing at all
        s_leg, _ = engine.run(
            dataclasses.replace(cfg, chan_model="legacy"), tr
        )
        m_leg = engine.summarize(s_leg, cfg)
        assert m["read_lat_p99_us"] > m_leg["read_lat_p99_us"]
        assert m_leg["read_chan_wait_us"] == 0.0


class TestMultiPlaneOverlap:
    """Lattice background charging: co-scheduled plane ops on one die pay
    one command + the max of the per-plane times, not the sum."""

    def _erase_two_plane_delta(self, chan_model):
        cfg = geometry.tiny_config(planes_per_lun=2, chan_model=chan_model)
        s = st.init_state(cfg)
        # blocks 0 and n_dies: same die 0, planes 0 and 1
        victims = jnp.asarray([0, cfg.n_dies], jnp.int32)
        grp = jnp.ones((2,), bool)
        before = np.asarray(s.die_busy_ms).copy()
        s2 = ftl._erase_many(s, victims, grp, cfg)
        return np.asarray(s2.die_busy_ms) - before, cfg

    def test_two_plane_erase_charges_max_not_sum(self):
        delta_lat, cfg = self._erase_two_plane_delta("lattice")
        delta_leg, _ = self._erase_two_plane_delta("legacy")
        erase_ms = float(modes.ERASE_LATENCY_US[modes.QLC]) / 1000.0
        assert delta_lat[0] == pytest.approx(erase_ms)  # overlapped
        assert delta_leg[0] == pytest.approx(2 * erase_ms)  # serialized
        assert (delta_lat[1:] == 0).all() and (delta_leg[1:] == 0).all()

    def test_single_plane_lattice_charges_match_legacy(self):
        """At planes_per_lun=1 the lattice traces the very same sequential
        charging ops as legacy (no segment-reassociation), keeping the
        degenerate identity bitwise."""
        cfg = geometry.tiny_config(
            policy=geometry.RARO, initial_pe=500, gc_free_threshold=6,
        )
        tr = workload.mixed_trace(cfg, 8 * cfg.chunk, theta=1.0,
                                  read_frac=0.5, seed=11)
        s_leg, _ = engine.run(cfg, tr)
        s_lat, _ = engine.run(
            dataclasses.replace(cfg, chan_model="lattice"), tr
        )
        np.testing.assert_array_equal(np.asarray(s_leg.die_busy_ms),
                                      np.asarray(s_lat.die_busy_ms))

    def test_multi_plane_lattice_run_executes(self):
        """End-to-end smoke at planes_per_lun=2: the lattice run completes
        with background overlap active, and overlapped charging can only
        shrink busy time relative to legacy serialization."""
        cfg = geometry.tiny_config(
            planes_per_lun=2, policy=geometry.RARO, initial_pe=500,
        )
        tr = workload.mixed_trace(cfg, 8 * cfg.chunk, theta=1.0,
                                  read_frac=0.5, seed=11)
        s_leg, _ = engine.run(cfg, tr)
        s_lat, _ = engine.run(
            dataclasses.replace(cfg, chan_model="lattice"), tr
        )
        assert float(s_lat.n_reads) == float(s_leg.n_reads)
        assert (np.asarray(s_lat.die_busy_ms)
                <= np.asarray(s_leg.die_busy_ms) + 1e-4).all()


class TestFaultsEntity:
    """Satellite: the erase-fault draw is keyed on the block's lattice
    coordinates; under the striped layout that packs back to the raw block
    id, so zero-rate and legacy draws are pinned unchanged."""

    def test_entity_equals_block_id_under_striping(self):
        for d, p in [(4, 1), (4, 2), (2, 4), (8, 2), (3, 5)]:
            blk = np.arange(d * p * 7)
            np.testing.assert_array_equal(
                np.asarray(flt.block_entity(blk, d, p)), blk
            )

    def test_erase_draws_unchanged(self):
        params = flt.FaultParams(
            max_read_retries=jnp.int32(-1),
            prog_fail_rate=jnp.float32(0.0),
            erase_fail_rate=jnp.float32(0.5),
            read_fail_rate=jnp.float32(0.0),
            wear_slope=jnp.float32(0.0),
            parity_rebuild=jnp.int32(0),
            seed=jnp.int32(3),
            read_recovery_us=5000.0,
            wear_power=4.0,
        )
        blocks = jnp.arange(256, dtype=jnp.int32)
        pe = jnp.full((256,), 17, jnp.int32)
        rated = jnp.full((256,), 3_000, jnp.int32)
        raw = np.asarray(flt.erase_fails(params, blocks, pe, rated))
        keyed = np.asarray(flt.erase_fails(
            params, flt.block_entity(blocks, 4, 2), pe, rated
        ))
        np.testing.assert_array_equal(raw, keyed)
        assert raw.any() and not raw.all()  # the draw is non-trivial

    def test_zero_rate_lattice_run_draws_nothing(self):
        cfg = geometry.tiny_config(
            chan_model="lattice", policy=geometry.RARO, initial_pe=500,
            erase_fail_rate=0.0, prog_fail_rate=0.0, max_read_retries=40,
        )
        tr = workload.mixed_trace(cfg, 6 * cfg.chunk, theta=1.0,
                                  read_frac=0.6, seed=2)
        s, _ = engine.run(cfg, tr)
        assert float(s.n_erase_fails) == 0.0
        assert float(s.n_prog_fails) == 0.0
        assert float(s.bad_count) == 0.0
