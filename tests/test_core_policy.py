"""Unit + property tests for heat classification, Table-II policy, controller
aggregation and elastic reclaim."""

import jax.numpy as jnp
import numpy as np
from hyp_fallback import given, settings, st

from repro.core import controller, hotness, modes, policy, reclaim

CFG = hotness.HeatConfig(decay=0.9, hot_thresh=8.0, warm_thresh=2.0)


class TestHotness:
    def test_classify_thresholds(self):
        h = jnp.array([0.0, 1.9, 2.0, 7.9, 8.0, 100.0])
        c = hotness.classify(h, CFG)
        np.testing.assert_array_equal(np.array(c), [0, 0, 1, 1, 2, 2])

    def test_decay_to_cold(self):
        h = jnp.full((4,), 10.0)
        for _ in range(60):
            h = hotness.decay_heat(h, CFG)
        assert int(hotness.classify(h, CFG)[0]) == modes.COLD

    def test_update_accumulates_duplicates(self):
        h = jnp.zeros(4)
        h = hotness.update_heat(h, jnp.array([1, 1, 1, 2]), CFG)
        assert float(h[1]) == 3.0 and float(h[2]) == 1.0


class TestTableII:
    def _th(self):
        return policy.Thresholds(jnp.int32(1), jnp.int32(5))

    def test_qlc_hot_to_slc(self):
        t = policy.migration_decision(modes.QLC, modes.HOT, 1, self._th())
        assert int(t) == modes.SLC

    def test_qlc_warm_to_tlc_requires_r2(self):
        th = self._th()
        assert int(policy.migration_decision(modes.QLC, modes.WARM, 4, th)) == modes.QLC
        assert int(policy.migration_decision(modes.QLC, modes.WARM, 5, th)) == modes.TLC

    def test_tlc_hot_to_slc(self):
        assert int(policy.migration_decision(modes.TLC, modes.HOT, 1, self._th())) == modes.SLC

    def test_cold_never_migrates(self):
        for m in (modes.QLC, modes.TLC, modes.SLC):
            assert int(policy.migration_decision(m, modes.COLD, 16, self._th())) == m

    def test_slc_never_converts_further(self):
        for h in (modes.COLD, modes.WARM, modes.HOT):
            assert int(policy.migration_decision(modes.SLC, h, 16, self._th())) == modes.SLC

    def test_below_r1_stays(self):
        assert int(policy.migration_decision(modes.QLC, modes.HOT, 0, self._th())) == modes.QLC

    def test_stage_r2_schedule(self):
        th = policy.stage_thresholds(jnp.array([100, 500, 900]))
        np.testing.assert_array_equal(np.array(th.r2), [5, 7, 11])

    @given(
        mode=st.integers(0, 2),
        heat=st.integers(0, 2),
        retries=st.integers(0, 16),
        r1=st.integers(0, 4),
        dr2=st.integers(0, 12),
    )
    @settings(max_examples=200, deadline=None)
    def test_property_monotone_and_no_densification(self, mode, heat, retries, r1, dr2):
        """Invariants: (a) conversion never increases density; (b) RARO
        triggers imply the Hotness scheme would also trigger (RARO is a
        strict filter on Hotness, which is WHY capacity loss shrinks)."""
        th = policy.Thresholds(jnp.int32(r1), jnp.int32(r1 + dr2))
        t = int(policy.migration_decision(mode, heat, retries, th))
        assert t <= mode  # never to a denser mode
        h = int(policy.hotness_only_decision(mode, heat))
        if t != mode:  # RARO migrated => Hotness migrates at least as far down
            assert h <= t


class TestController:
    def test_block_plan_min_target_wins(self):
        # 2 blocks x 3 pages; block 0 has one page wanting SLC, one TLC.
        page_block = jnp.array([0, 0, 0, 1, 1, 1])
        page_mode = jnp.full(6, modes.QLC, jnp.int32)
        page_target = jnp.array([modes.SLC, modes.TLC, modes.QLC, modes.QLC, modes.QLC, modes.QLC])
        valid = jnp.ones(6, bool)
        bm = jnp.full(2, modes.QLC, jnp.int32)
        plan = controller.block_conversion_plan(page_target, page_mode, page_block, valid, 2, bm)
        np.testing.assert_array_equal(np.array(plan), [modes.SLC, modes.QLC])

    def test_invalid_pages_do_not_trigger(self):
        page_block = jnp.array([0, 0])
        page_mode = jnp.full(2, modes.QLC, jnp.int32)
        page_target = jnp.array([modes.SLC, modes.QLC])
        valid = jnp.array([False, True])
        bm = jnp.full(1, modes.QLC, jnp.int32)
        plan = controller.block_conversion_plan(page_target, page_mode, page_block, valid, 1, bm)
        assert int(plan[0]) == modes.QLC


class TestReclaim:
    def test_no_demotion_without_pressure(self):
        mode = jnp.array([modes.SLC, modes.TLC])
        m, _ = reclaim.select_demotions(mode, jnp.zeros(2), jnp.full(2, 10), 0.9, reclaim.ReclaimConfig())
        assert int(m.sum()) == 0

    def test_demotes_one_level_only(self):
        mode = jnp.array([modes.SLC, modes.TLC, modes.QLC])
        m, t = reclaim.select_demotions(mode, jnp.zeros(3), jnp.full(3, 10), 0.01, reclaim.ReclaimConfig())
        assert bool(m[0]) and bool(m[1]) and not bool(m[2])
        assert int(t[0]) == modes.TLC and int(t[1]) == modes.QLC

    def test_hysteresis_cold_epochs(self):
        mode = jnp.array([modes.SLC])
        m, _ = reclaim.select_demotions(mode, jnp.zeros(1), jnp.array([1]), 0.01,
                                        reclaim.ReclaimConfig(cold_epochs=4))
        assert int(m.sum()) == 0
