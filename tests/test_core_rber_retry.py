"""Unit tests for the Eq.(1) RBER model and Eq.(2)/(3) retry model."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import modes, policy, rber, retry


class TestRBER:
    def test_monotone_in_cycles(self):
        c = jnp.array([0.0, 100.0, 400.0, 900.0])
        r = rber.rber(modes.QLC, c, 10.0, 10.0)
        assert np.all(np.diff(np.array(r)) > 0)

    def test_monotone_in_time_and_reads(self):
        base = rber.rber(modes.QLC, 500.0, 10.0, 10.0)
        assert rber.rber(modes.QLC, 500.0, 200.0, 10.0) > base
        assert rber.rber(modes.QLC, 500.0, 10.0, 2000.0) > base

    def test_mode_ordering(self):
        # Denser modes are strictly less reliable at identical stress.
        s = rber.rber(modes.SLC, 500.0, 100.0, 100.0)
        t = rber.rber(modes.TLC, 500.0, 100.0, 100.0)
        q = rber.rber(modes.QLC, 500.0, 100.0, 100.0)
        assert s < t < q

    def test_page_variation_deterministic_and_centered(self):
        ids = jnp.arange(50_000)
        f = np.array(rber.page_variation(ids))
        f2 = np.array(rber.page_variation(ids))
        np.testing.assert_array_equal(f, f2)
        # lognormal(0, sigma): median ~ 1
        assert 0.95 < np.median(f) < 1.05
        assert np.all(f > 0)


class TestRetry:
    def test_zero_retries_when_ldpc_corrects_first_read(self):
        # RBER small enough that a * RBER * n_sense <= E_LDPC
        n = retry.retry_count(modes.QLC, retry.E_LDPC_RATE / 8.0 * 0.9)
        assert int(n) == 0

    def test_eq3_inverse(self):
        # Check Eq.(2) holds at the returned count: RBER*ns*(1-d)^n <= E.
        for r in [2e-3, 5e-3, 1e-2, 3e-2]:
            n = int(retry.retry_count(modes.QLC, r))
            lhs = r * 8 * (1 - retry.DELTA) ** n
            assert lhs <= retry.E_LDPC_RATE or n == int(modes.MAX_RETRIES[modes.QLC])

    def test_clipped_to_table_max(self):
        n = retry.retry_count(modes.QLC, 0.5)
        assert int(n) == int(modes.MAX_RETRIES[modes.QLC])

    def test_latency_model_matches_fig4(self):
        # Fig 4: 1 retry => -50% bandwidth (2x latency); 10 retries => ~-92%.
        base = float(retry.read_latency_us(modes.QLC, 0))
        one = float(retry.read_latency_us(modes.QLC, 1))
        ten = float(retry.read_latency_us(modes.QLC, 10))
        assert one == pytest.approx(2 * base)
        assert 1 - base / ten == pytest.approx(0.909, abs=0.02)


class TestCalibration:
    """DESIGN.md §6 — distributions must land in the paper's Fig. 5/6 bands."""

    @pytest.fixture(scope="class")
    def pages(self):
        return jnp.arange(20_000)

    def _dist(self, mode, lo, hi, pages, seed=0):
        # "typical workload stress": pages in blocks that have accumulated
        # reads (Fig. 6 is measured during the Zipf read workload)
        cyc = np.random.RandomState(seed).uniform(lo, hi, len(pages))
        return np.array(retry.page_retries(mode, cyc, 100.0, 2000.0, pages))

    def test_qlc_young(self, pages):
        n = self._dist(modes.QLC, 0, 333, pages)
        assert 4 <= np.median(n) <= 7
        assert np.percentile(n, 95) <= 11

    def test_qlc_middle(self, pages):
        n = self._dist(modes.QLC, 334, 666, pages)
        assert 7 <= np.median(n) <= 12

    def test_qlc_old(self, pages):
        n = self._dist(modes.QLC, 667, 1000, pages)
        assert 11 <= np.median(n) <= 15
        # paper: max-retry (16) pages ~ 9.71% at old stage
        assert 0.04 <= np.mean(n == 16) <= 0.18

    def test_lightly_stressed_pages_sit_below_r2(self, pages):
        # Paper §V-C picks R2 at the LOW end of each stage band: warm data in
        # lightly-read blocks must mostly NOT pass R2 (this is what saves
        # capacity vs the Hotness scheme).
        for (lo, hi), r2 in [((0, 333), 5), ((334, 666), 7), ((667, 1000), 11)]:
            cyc = np.random.RandomState(1).uniform(lo, hi, len(pages))
            n = np.array(retry.page_retries(modes.QLC, cyc, 24.0, 50.0, pages))
            assert np.mean(n >= r2) < 0.40

    def test_heavily_read_pages_rise_above_r2(self, pages):
        # ... while read-disturbed hot blocks DO pass (the trigger works).
        for (lo, hi), r2 in [((0, 333), 5), ((334, 666), 7), ((667, 1000), 11)]:
            cyc = np.random.RandomState(2).uniform(lo, hi, len(pages))
            n = np.array(retry.page_retries(modes.QLC, cyc, 100.0, 5000.0, pages))
            assert np.mean(n >= r2) > 0.60

    def test_tlc_much_less_severe_than_qlc(self, pages):
        for lo, hi in [(0, 333), (334, 666), (667, 1000)]:
            q = self._dist(modes.QLC, lo, hi, pages)
            t = self._dist(modes.TLC, lo, hi, pages)
            assert np.median(t) <= np.median(q) - 3

    def test_fresh_tlc_at_most_one_retry(self, pages):
        # paper §V-C: converted TLC "does not exceed 1" retry under typical
        # load -> this is why R1 = 1.
        n = np.array(retry.page_retries(modes.TLC, 500.0, 0.5, 1.0, pages))
        assert np.percentile(n, 99) <= policy.DEFAULT_R1

    def test_slc_retry_free(self, pages):
        n = np.array(retry.page_retries(modes.SLC, 900.0, 500.0, 10_000.0, pages))
        assert n.max() == 0
