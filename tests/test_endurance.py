"""Endurance / WAF / unified victim-scorer tests (DESIGN.md §2E).

Pinned here:

  1. The WAF accounting identity — ``waf == (user + reloc) / user`` holds
     exactly across closed-loop, open-loop (legacy and lattice) and
     faults-armed runs, and the relocation counter matches both
     ``n_migrated_pages`` (fault-free) and the page count decoded from the
     PR 6 event ring when nothing is dropped.
  2. Default-scorer bit-identity — ``reclaim.score_victims`` with the
     ``min_valid`` objective (static or knob code 0) selects exactly the
     blocks the historical inline top-k picked, property-tested on real
     engine states against a numpy greedy reference.
  3. The lifespan scorer formula, its wear sensitivity, and the
     ``gc_objective`` sweep axis (the min-valid point of a mixed-objective
     batch equals the knob-free run bit for bit).
  4. The deprecated wrappers (``select_demotions`` /
     ``select_demotion_victims`` / ``topk_victims``) — equivalent to the
     unified entry point, and they warn exactly once.
  5. DWPD / TBW / lifetime-years conversion-helper arithmetic.
"""

import dataclasses
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import modes, reclaim
from repro.experiments import registry, sweep
from repro.ssdsim import engine, geometry, obs, policies, workload
from repro.ssdsim import state as st

# small high-occupancy geometry: GC fires within a few chunks, so the WAF
# numerator is nonzero on a 24-chunk trace
CFG = geometry.tiny_config(gc_free_threshold=6, n_logical=2_944,
                           initial_pe=500)
N_REQ = 24 * CFG.chunk


def _trace(cfg, seed=0, read_frac=0.3):
    return registry.build("mixed", cfg, N_REQ, seed=seed,
                          read_frac=read_frac)


def _run(cfg, trace):
    s, _ = engine.run(cfg, trace)
    return jax.device_get(s)


def _waf_checks(m, *, expect_reloc_eq_migrated=True):
    assert m["user_pages"] > 0
    assert m["reloc_pages"] > 0, "trace must actually trigger relocation"
    assert m["waf"] == (m["user_pages"] + m["reloc_pages"]) / m["user_pages"]
    assert m["user_pages"] == m["writes"]
    if expect_reloc_eq_migrated:
        # fault-free: every relocation booked by relocate_group/migrate_pages
        # lands in _place_pages, so the two counters agree exactly
        assert m["reloc_pages"] == m["migrated_pages"]


class TestWafIdentity:
    def test_closed_loop(self):
        m = engine.summarize(_run(CFG, _trace(CFG)), CFG)
        _waf_checks(m)

    def test_open_loop_legacy(self):
        tr = workload.attach_arrivals(CFG, _trace(CFG), 30_000.0, seed=7)
        m = engine.summarize(_run(CFG, tr), CFG)
        _waf_checks(m)

    def test_open_loop_lattice(self):
        cfg = dataclasses.replace(CFG, chan_model="lattice")
        tr = workload.attach_arrivals(cfg, _trace(cfg), 30_000.0, seed=7)
        m = engine.summarize(_run(cfg, tr), cfg)
        _waf_checks(m)

    def test_faults_armed(self):
        # erase failures + a finite retry budget armed, prog_fail_rate = 0 so
        # no re-placements perturb the reloc == migrated equality
        cfg = dataclasses.replace(CFG, erase_fail_rate=0.05,
                                  max_read_retries=4, fault_seed=3)
        m = engine.summarize(_run(cfg, _trace(cfg)), cfg)
        _waf_checks(m)

    def test_prog_fail_replacement_counts_as_amplification(self):
        cfg = dataclasses.replace(CFG, prog_fail_rate=0.05, fault_seed=3)
        m = engine.summarize(_run(cfg, _trace(cfg)), cfg)
        _waf_checks(m, expect_reloc_eq_migrated=False)
        assert m["prog_fails"] > 0
        # re-placed pages are write amplification but not "migrations"
        assert m["reloc_pages"] > m["migrated_pages"]

    def test_matches_event_ring(self):
        # full instruments, capacity large enough that nothing is dropped:
        # the decoded per-event page counts must reproduce the counter
        cfg = dataclasses.replace(CFG, obs_level="full",
                                  obs_event_capacity=4_096)
        s = _run(cfg, _trace(cfg))
        m = engine.summarize(s, cfg)
        records, total, dropped = obs.decode_events(s, cfg)
        assert dropped == 0
        reloc_reasons = {obs.REASON_CONV_PAGE, obs.REASON_GC,
                         obs.REASON_RECLAIM, obs.REASON_CONV_BLOCK}
        ring_pages = sum(r["pages"] for r in records
                         if r["reason"] in reloc_reasons)
        assert ring_pages == m["reloc_pages"]
        _waf_checks(m)

    def test_read_only_waf_is_one(self):
        cfg = geometry.tiny_config()
        tr = registry.build("zipf", cfg, 8 * cfg.chunk, seed=0)
        m = engine.summarize(_run(cfg, tr), cfg)
        assert m["user_pages"] == 0.0
        assert m["waf"] == 1.0
        assert m["lifetime_years"] == 0.0 and m["dwpd"] == 0.0


# ------------------- default-scorer bit-identity (tentpole) ----------------


def _legacy_min_valid(s, cfg, k):
    """The historical inline GC selection, reproduced op for op."""
    ppb = geometry.pages_per_block(cfg)
    reclaimable = (s.block_state == st.FULL) & (s.block_valid < ppb[s.block_mode])
    masked = jnp.where(reclaimable, -s.block_valid.astype(jnp.float32), -jnp.inf)
    vals, victims = jax.lax.top_k(masked, k)
    return victims.astype(jnp.int32), vals > -jnp.inf


class TestDefaultScorerBitIdentity:
    @pytest.fixture(scope="class")
    def real_states(self):
        """Real engine states at several wear points / seeds."""
        out = []
        for seed, pe in ((0, 500), (1, 900)):
            cfg = dataclasses.replace(CFG, initial_pe=pe)
            out.append((jax.device_get(_run(cfg, _trace(cfg, seed=seed))), cfg))
        return out

    def test_property_matches_legacy_ops(self, real_states):
        for s, cfg in real_states:
            for k in (1, 2, 4):
                v_ref, ok_ref = _legacy_min_valid(s, cfg, k)
                v, ok, tgt = reclaim.score_victims(s, cfg, "min_valid", k=k)
                np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))
                np.testing.assert_array_equal(np.asarray(ok), np.asarray(ok_ref))
                # GC relocates at the victim's own density
                np.testing.assert_array_equal(
                    np.asarray(tgt), np.asarray(s.block_mode)[np.asarray(v)])

    def test_property_matches_numpy_greedy(self, real_states):
        for s, cfg in real_states:
            ppb = np.asarray(geometry.pages_per_block_host(cfg))
            valid = np.asarray(s.block_valid)
            mode = np.asarray(s.block_mode)
            reclaimable = ((np.asarray(s.block_state) == st.FULL)
                           & (valid < ppb[mode]))
            cand = np.flatnonzero(reclaimable)
            greedy = cand[np.lexsort((cand, valid[cand]))]
            k = 4
            v, ok, _ = reclaim.score_victims(s, cfg, "min_valid", k=k)
            n = min(k, len(greedy))
            np.testing.assert_array_equal(np.asarray(v)[:n], greedy[:n])
            np.testing.assert_array_equal(
                np.asarray(ok), np.arange(k) < len(greedy))

    def test_knob_code_zero_is_bit_identical(self, real_states):
        for s, cfg in real_states:
            v_ref, ok_ref = _legacy_min_valid(s, cfg, 4)
            v, ok, _ = reclaim.score_victims(
                s, cfg, "min_valid", k=4, objective_code=jnp.int32(0))
            np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))
            np.testing.assert_array_equal(np.asarray(ok), np.asarray(ok_ref))

    def test_full_run_unchanged_by_scorer_refactor(self):
        # the engine's own GC path (routed through score_victims) must keep
        # producing the historical states: pin a couple of headline counters
        # against the reference scalar GC in a k=1 config where the two are
        # guaranteed identical (covered in depth by test_relocation.py)
        cfg = dataclasses.replace(CFG, gc_victims_per_pass=1)
        tr = _trace(cfg)
        s = _run(cfg, tr)
        assert float(s.n_reloc_pages) == float(s.n_migrated_pages)


# ----------------------------- lifespan scorer -----------------------------


class TestLifespanScorer:
    def _toy_state(self):
        # four FULL QLC blocks: equal-valid pairs with different wear
        return SimpleNamespace(
            block_valid=jnp.array([10, 10, 50, 50], jnp.int32),
            block_mode=jnp.full((4,), modes.QLC, jnp.int32),
            block_state=jnp.full((4,), st.FULL, jnp.int32),
            block_pe=jnp.array([900, 100, 100, 900], jnp.int32),
        )

    def test_formula(self):
        cfg = geometry.tiny_config(gc_objective="lifespan", gc_alpha=1.0,
                                   gc_beta=0.5, gc_gamma=0.3)
        s = self._toy_state()
        ppb = geometry.pages_per_block(cfg)
        mig = np.asarray(s.block_valid, np.float32) / np.asarray(
            ppb, np.float32)[np.asarray(s.block_mode)]
        pe_norm = np.asarray(s.block_pe, np.float32) / np.asarray(
            modes.PE_LIMIT, np.float32)[np.asarray(s.block_mode)]
        expect = (cfg.gc_alpha * (1.0 - mig) - cfg.gc_beta * mig
                  - cfg.gc_gamma * pe_norm)
        got = np.asarray(reclaim.gc_scores(s, cfg, "lifespan"))
        np.testing.assert_allclose(got, expect, rtol=1e-6)

    def test_prefers_less_worn_block_on_valid_ties(self):
        cfg = geometry.tiny_config(gc_objective="lifespan")
        v, ok, _ = reclaim.score_victims(self._toy_state(), cfg, "lifespan", k=2)
        # blocks 0/1 tie on invalid ratio; γ > 0 breaks the tie toward the
        # younger block 1 (min_valid would pick block 0 by index order)
        assert int(v[0]) == 1 and int(v[1]) == 0

    def test_invalid_ratio_dominates(self):
        cfg = geometry.tiny_config(gc_objective="lifespan")
        v, _, _ = reclaim.score_victims(self._toy_state(), cfg, "lifespan", k=4)
        # the 10-valid pair beats the 50-valid pair regardless of wear
        assert set(np.asarray(v)[:2].tolist()) == {0, 1}

    def test_knob_code_selects_lifespan(self):
        cfg = geometry.tiny_config()  # static default: min_valid
        s = self._toy_state()
        v_life, _, _ = reclaim.score_victims(
            s, cfg, "min_valid", k=1, objective_code=jnp.int32(1))
        v_static, _, _ = reclaim.score_victims(s, cfg, "lifespan", k=1)
        assert int(v_life[0]) == int(v_static[0]) == 1

    def test_engine_gc_path_honours_objective(self):
        # the engine's GC entry point (ftl.select_gc_victims) must route
        # cfg.gc_objective / knobs.gc_objective into the scorer: on a real
        # engine state with striped wear, a heavy γ flips the victim choice
        from repro.ssdsim import ftl

        cfg = dataclasses.replace(CFG, gc_free_threshold=50)
        s = _run(cfg, _trace(cfg))
        # stripe the wear so equal-valid candidates differ in P/E
        pe = 100 + 800 * (np.arange(s.block_pe.shape[0]) % 2)
        s = s._replace(block_pe=jnp.asarray(pe, jnp.int32))
        v_mv, ok_mv = ftl.select_gc_victims(s, cfg, 4)
        cfg_l = dataclasses.replace(cfg, gc_objective="lifespan",
                                    gc_gamma=1e4)
        v_ls, ok_ls = ftl.select_gc_victims(s, cfg_l, 4)
        assert bool(ok_mv.all()) and bool(ok_ls.all())
        assert not np.array_equal(np.asarray(v_mv), np.asarray(v_ls))
        # γ=1e4 dominates: every lifespan victim comes from the young stripe
        assert (np.asarray(s.block_pe)[np.asarray(v_ls)] == 100).all()
        # a traced knob code overrides the static objective identically
        knobs = policies.RunKnobs(
            r1=jnp.int32(1), r2_override=jnp.int32(-1),
            initial_pe=jnp.int32(500), gc_objective=jnp.int32(1))
        v_knob, _ = ftl.select_gc_victims(s, cfg_l, 4, knobs)
        np.testing.assert_array_equal(np.asarray(v_knob), np.asarray(v_ls))

    def test_unknown_objective_rejected(self):
        with pytest.raises(ValueError):
            reclaim.score_victims(self._toy_state(), CFG, "nope", k=1)
        with pytest.raises(ValueError):
            geometry.tiny_config(gc_objective="nope")

    def test_objective_tables_consistent(self):
        assert geometry.GC_OBJECTIVES == reclaim.GC_OBJECTIVES
        assert (set(reclaim.GC_OBJECTIVE_CODES)
                == set(reclaim.GC_OBJECTIVES))


# --------------------------- gc_objective sweep axis -----------------------


class TestSweepAxis:
    def _spec(self, **kw):
        return sweep.SweepSpec(
            scenario="mixed", n_requests=8 * CFG.chunk,
            policies=(geometry.BASELINE,), initial_pe=(500,), seeds=(0,),
            scenario_kw=(("read_frac", 0.3),), base=CFG, **kw,
        )

    def test_expand_tag_and_n_runs(self):
        spec = self._spec(gc_objective=("min_valid", "lifespan"))
        runs = sweep.expand(spec)
        assert len(runs) == spec.n_runs() == 2
        tags = [r.tag() for r in runs]
        assert any(t.endswith("gc_lifespan") for t in tags)
        # the default objective never pollutes existing tags (checkpoint and
        # artifact names from older sweeps stay valid)
        assert all("gc_min_valid" not in t for t in tags)

    def test_min_valid_point_bit_identical_to_knob_free_run(self):
        res0 = sweep.run_sweep(self._spec())
        res1 = sweep.run_sweep(
            self._spec(gc_objective=("min_valid", "lifespan")))
        assert len(res0) == 1 and len(res1) == 2
        mv = next(r for r in res1 if r["run"]["gc_objective"] == "min_valid")
        for k, v in res0[0].items():
            if k == "run":
                continue
            np.testing.assert_array_equal(
                np.asarray(v), np.asarray(mv[k]), err_msg=k)
        # both objectives actually produced endurance rows
        for r in res1:
            assert r["waf"] >= 1.0 and r["lifetime_years"] >= 0.0
            assert r["pe_variance"] >= 0.0


# --------------------------- deprecated wrappers ---------------------------


class TestDeprecatedWrappers:
    def _args(self, seed=0):
        rng = np.random.default_rng(seed)
        B = 16
        block_mode = jnp.asarray(rng.integers(0, 3, B), jnp.int32)
        block_heat = jnp.asarray(rng.random(B), jnp.float32)
        cold_age = jnp.asarray(rng.integers(0, 10, B), jnp.int32)
        return block_mode, block_heat, cold_age

    def test_select_demotion_victims_equivalent(self):
        cfg = reclaim.ReclaimConfig()
        for seed in range(4):
            mode, heat, age = self._args(seed)
            with pytest.warns(DeprecationWarning) if seed == 0 else _nullctx():
                reclaim._DEPRECATED_WARNED.discard("select_demotion_victims")
                v_old, ok_old, t_old = reclaim.select_demotion_victims(
                    mode, heat, age, 0.05, cfg)
            # the historical implementation, op for op
            scores = reclaim.demotion_scores(mode, heat, age)
            eligible = (scores > -jnp.inf) & (age >= cfg.cold_epochs)
            v_ref, ok_ref = reclaim._topk(scores, eligible & jnp.bool_(True),
                                          min(cfg.max_per_pass, 16))
            t_ref = jnp.minimum(mode[v_ref] + 1, modes.QLC)
            np.testing.assert_array_equal(np.asarray(v_old), np.asarray(v_ref))
            np.testing.assert_array_equal(np.asarray(ok_old), np.asarray(ok_ref))
            np.testing.assert_array_equal(np.asarray(t_old), np.asarray(t_ref))

    def test_select_demotions_equivalent_to_dense_reference(self):
        cfg = reclaim.ReclaimConfig()
        for seed in range(4):
            for free_frac in (0.05, 0.9):
                mode, heat, age = self._args(seed)
                reclaim._DEPRECATED_WARNED.discard("select_demotions")
                mask, target = reclaim.select_demotions(
                    mode, heat, age, free_frac, cfg)
                # historical dense-mask implementation
                scores = reclaim.demotion_scores(mode, heat, age)
                eligible = (scores > -jnp.inf) & (age >= cfg.cold_epochs)
                under = free_frac < cfg.low_watermark
                k = min(cfg.max_per_pass, 16)
                masked = jnp.where(eligible, scores, -jnp.inf)
                _, top = jax.lax.top_k(masked, k)
                m_ref = jnp.zeros(16, bool).at[top].set(True) & eligible & under
                t_ref = jnp.where(m_ref, jnp.minimum(mode + 1, modes.QLC), mode)
                np.testing.assert_array_equal(np.asarray(mask), np.asarray(m_ref))
                np.testing.assert_array_equal(np.asarray(target), np.asarray(t_ref))

    def test_wrappers_warn_once(self):
        mode, heat, age = self._args()
        for name, call in (
            ("topk_victims",
             lambda: reclaim.topk_victims(heat, mode >= 0, 2)),
            ("select_demotions",
             lambda: reclaim.select_demotions(mode, heat, age, 0.05,
                                              reclaim.ReclaimConfig())),
            ("select_demotion_victims",
             lambda: reclaim.select_demotion_victims(
                 mode, heat, age, 0.05, reclaim.ReclaimConfig())),
        ):
            reclaim._DEPRECATED_WARNED.discard(name)
            with pytest.warns(DeprecationWarning, match=name):
                call()
            with no_warns(DeprecationWarning):
                call()

    def test_engine_hot_path_never_warns(self):
        # the production demotion/GC paths use score_victims directly
        with no_warns(DeprecationWarning):
            _run(CFG, _trace(CFG))


# ------------------------- conversion helpers (modes) ----------------------


class TestEnduranceHelpers:
    def test_rated_pe_host_table_matches_device_table(self):
        np.testing.assert_array_equal(np.asarray(modes.PE_LIMIT),
                                      np.asarray(modes.RATED_PE))

    def test_tbw(self):
        cap = 16 * 2**30
        assert modes.tbw_bytes(cap, 1_000, waf=1.0) == cap * 1_000
        assert modes.tbw_bytes(cap, 1_000, waf=2.0) == cap * 500

    def test_lifetime_roundtrip(self):
        cap = 16 * 2**30
        tbw = modes.tbw_bytes(cap, 1_000, waf=1.25)
        rate = 3 * cap  # 3 drive writes per day
        assert modes.dwpd(rate, cap) == 3.0
        yrs = modes.lifetime_years(tbw, rate)
        assert yrs == pytest.approx(tbw / (rate * 365.25))
        # dwpd_for_lifetime inverts lifetime_years at the same TBW
        assert modes.dwpd_for_lifetime(tbw, cap, yrs) == pytest.approx(3.0)

    def test_no_writes_sentinel(self):
        assert modes.lifetime_years(1e15, 0.0) == 0.0


# ----------------------------- warning helpers -----------------------------


import contextlib  # noqa: E402


@contextlib.contextmanager
def _nullctx():
    yield


@contextlib.contextmanager
def no_warns(category):
    import warnings as _w
    with _w.catch_warnings(record=True) as rec:
        _w.simplefilter("always")
        yield
    hits = [r for r in rec if issubclass(r.category, category)]
    assert not hits, f"unexpected {category.__name__}: {hits[0].message}"
