"""Tests for the experiments subsystem: latency-histogram telemetry,
scenario generators, MSR trace replay, and the vmapped sweep runner."""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.experiments import registry, scenarios, sweep, traces
from repro.ssdsim import engine, geometry, telemetry, workload
from repro.ssdsim import state as st
from repro.ssdsim.engine import OP_READ, OP_WRITE

TINY = geometry.tiny_config()


class TestTelemetry:
    def test_bin_edges_monotone_log_spaced(self):
        e = telemetry.bin_edges_us()
        assert e.shape == (telemetry.N_LAT_BINS + 1,)
        ratios = e[1:] / e[:-1]
        np.testing.assert_allclose(ratios, ratios[0], rtol=1e-9)

    def test_latency_bin_brackets_edges(self):
        e = telemetry.bin_edges_us()
        # values inside bin i land in bin i; extremes clip
        mids = np.sqrt(e[:-1] * e[1:])
        idx = np.asarray(telemetry.latency_bin(jnp.asarray(mids, jnp.float32)))
        np.testing.assert_array_equal(idx, np.arange(telemetry.N_LAT_BINS))
        assert int(telemetry.latency_bin(1e-3)) == 0
        assert int(telemetry.latency_bin(1e9)) == telemetry.N_LAT_BINS - 1

    def test_record_masks_and_counts(self):
        h = jnp.zeros((telemetry.N_LAT_BINS,), jnp.float32)
        lat = jnp.array([20.0, 140.0, 2000.0, 99.0])
        mask = jnp.array([True, True, True, False])
        h = telemetry.record(h, lat, mask)
        assert float(h.sum()) == 3.0

    def test_percentiles_match_numpy_on_synthetic_sample(self):
        rng = np.random.default_rng(0)
        lat = np.exp(rng.normal(np.log(200.0), 0.8, size=200_000))
        h = np.zeros(telemetry.N_LAT_BINS)
        idx = np.asarray(telemetry.latency_bin(jnp.asarray(lat, jnp.float32)))
        np.add.at(h, idx, 1.0)
        pct = telemetry.percentiles(h)
        for q in (0.5, 0.95, 0.99):
            exact = np.quantile(lat, q)
            assert abs(pct[q] - exact) / exact < 0.10, (q, pct[q], exact)

    def test_empty_histogram(self):
        pct = telemetry.percentiles(np.zeros(telemetry.N_LAT_BINS))
        assert all(v == 0.0 for v in pct.values())

    def test_single_bin_histogram_stays_in_bin(self):
        """All mass in one bin: every quantile must interpolate inside that
        bin's edges, never land in a neighboring empty bin."""
        edges = telemetry.bin_edges_us()
        for b in (0, 17, telemetry.N_LAT_BINS - 1):
            h = np.zeros(telemetry.N_LAT_BINS)
            h[b] = 7.0
            pct = telemetry.percentiles(h, qs=(0.5, 0.95, 0.999, 1.0))
            for q, v in pct.items():
                # 1-ulp slack: lo * (hi/lo)**1.0 re-rounds the upper edge
                assert edges[b] * (1 - 1e-12) <= v <= edges[b + 1] * (1 + 1e-12), (b, q, v)

    def test_exact_boundary_quantile(self):
        """Target count falling exactly on a cumulative boundary (q=0.5 of
        [2, 0, 2]) must resolve at the boundary, not inside the empty bin."""
        edges = telemetry.bin_edges_us()
        h = np.zeros(telemetry.N_LAT_BINS)
        h[0], h[2] = 2.0, 2.0
        pct = telemetry.percentiles(h, qs=(0.5,))
        assert pct[0.5] <= edges[1] * (1 + 1e-9)
        # monotone across the empty gap
        pct2 = telemetry.percentiles(h, qs=(0.5, 0.75, 0.999))
        assert pct2[0.5] <= pct2[0.75] <= pct2[0.999] <= edges[3]

    def test_target_overshoot_does_not_hit_empty_tail_bin(self):
        """np.sum (pairwise) can exceed np.cumsum[-1] (sequential) by an
        ulp, pushing q*total past the last cumulative count. Exercise the
        overshoot deterministically with q slightly above 1: the quantile
        must clamp to the last non-empty bin instead of interpolating inside
        the empty tail via the eps guard (returning ~80 ms for a histogram
        whose slowest sample is far faster)."""
        h = np.zeros(telemetry.N_LAT_BINS)
        h[:20] = 1.0  # empty tail from bin 20 on
        edges = telemetry.bin_edges_us()
        for q in (1.0, 1.0 + 1e-9):  # boundary + guaranteed overshoot
            pct = telemetry.percentiles(h, qs=(q,))
            assert pct[q] <= edges[20] * (1 + 1e-9), (q, pct[q])

    def test_engine_histogram_totals_reads(self):
        tr = workload.zipf_read_trace(TINY, 4_000, 1.2, seed=0)
        s, ys = engine.run(TINY, tr)
        assert float(s.lat_hist.sum()) == float(s.n_reads)
        # per-chunk histograms sum to the cumulative one
        np.testing.assert_allclose(
            np.asarray(ys.lat_hist).sum(0), np.asarray(s.lat_hist), rtol=1e-6
        )

    def test_summarize_percentiles_ordered(self):
        tr = workload.zipf_read_trace(TINY, 4_000, 1.2, seed=0)
        s, _ = engine.run(TINY, tr)
        m = engine.summarize(s, TINY)
        assert (m["read_lat_p50_us"] <= m["read_lat_p95_us"]
                <= m["read_lat_p99_us"] <= m["read_lat_p999_us"])
        assert m["read_lat_p50_us"] > 0


class TestScenarios:
    @pytest.mark.parametrize("name", ["hotspot_shift", "bursty", "diurnal",
                                      "write_burst_then_read",
                                      "read_disturb_hammer"])
    def test_shapes_range_and_determinism(self, name):
        a = registry.build(name, TINY, 3_000, seed=5)
        b = registry.build(name, TINY, 3_000, seed=5)
        assert a["lpn"].shape == a["op"].shape
        assert a["lpn"].shape[1] == TINY.chunk
        lpn = a["lpn"].reshape(-1)
        assert lpn.max() < TINY.n_logical and lpn.min() >= -1
        np.testing.assert_array_equal(a["lpn"], b["lpn"])
        np.testing.assert_array_equal(a["op"], b["op"])

    def test_unknown_scenario_raises(self):
        with pytest.raises(KeyError):
            registry.build("no_such_scenario", TINY, 100)

    def test_read_disturb_hammer_concentrates_reads(self):
        tr = scenarios.read_disturb_hammer(TINY, 8_000, seed=0, hammer_prob=0.8)
        lpn = tr["lpn"].reshape(-1)
        lpn = lpn[lpn >= 0]
        counts = np.bincount(lpn // TINY.slots_per_block, minlength=TINY.n_blocks)
        # >= 70% of reads land on the ~2 hammered blocks
        assert np.sort(counts)[-3:].sum() > 0.7 * len(lpn)

    def test_write_burst_then_read_phase_order(self):
        tr = scenarios.write_burst_then_read(TINY, 4_000, seed=0, write_frac=0.25)
        op = tr["op"].reshape(-1)[:4_000]
        n_w = int((op == OP_WRITE).sum())
        assert n_w == 1_000
        assert (op[:n_w] == OP_WRITE).all() and (op[n_w:] == OP_READ).all()

    def test_hotspot_shift_moves(self):
        tr = scenarios.hotspot_shift(TINY, 8_000, seed=0, n_phases=2,
                                     hot_frac=0.05, hot_prob=1.0)
        lpn = tr["lpn"].reshape(-1)[:8_000]
        assert np.median(lpn[:4_000]) != np.median(lpn[4_000:])


class TestTraceReplay:
    def test_parse_sample(self):
        rec = traces.parse_msr_csv(traces.SAMPLE_TRACE)
        assert len(rec["op"]) > 400
        assert set(np.unique(rec["op"])) <= {OP_READ, OP_WRITE}
        assert (rec["size"] > 0).all() and (rec["offset"] >= 0).all()
        assert (np.diff(rec["timestamp"]) >= 0).all()  # sorted

    def test_header_and_garbage_tolerated(self, tmp_path):
        p = tmp_path / "t.csv"
        p.write_text(
            "Timestamp,Hostname,DiskNumber,Type,Offset,Size,ResponseTime\n"
            "1000,h,0,Read,32768,32768,100\n"
            "not,a,valid,row\n"
            "2000,h,0,Write,0,16384,50\n"
        )
        rec = traces.parse_msr_csv(p)
        assert len(rec["op"]) == 2
        np.testing.assert_array_equal(rec["op"], [OP_READ, OP_WRITE])

    def test_page_expansion_and_wrap(self):
        rec = {
            "timestamp": np.array([0, 1], np.int64),
            "op": np.array([OP_READ, OP_WRITE], np.int32),
            # 2nd I/O straddles a page boundary -> 3 pages
            "offset": np.array([0, 16 * 1024 + 8192], np.int64),
            "size": np.array([16 * 1024, 2 * 16 * 1024], np.int64),
        }
        lpn, op, arr = traces.records_to_page_requests(TINY, rec)
        assert len(lpn) == 1 + 3
        assert (op == [OP_READ, OP_WRITE, OP_WRITE, OP_WRITE]).all()
        np.testing.assert_array_equal(lpn, [0, 1, 2, 3])
        # every page of an I/O inherits its arrival time (filetime ticks
        # rebased to ms: 1 tick = 100 ns = 1e-4 ms)
        np.testing.assert_allclose(arr, [0.0, 1e-4, 1e-4, 1e-4])

    def test_replay_end_to_end(self):
        tr = registry.build("msr_sample", TINY, 2_000, seed=0)
        s, _ = engine.run(TINY, tr)
        assert float(s.n_reads) + float(s.n_writes) == 2_000
        assert float(s.n_writes) > 0  # sample contains a write burst
        assert (np.asarray(s.l2p) >= 0).all()

    def test_cycle_fills_budget(self):
        tr = traces.replay_trace(TINY, traces.SAMPLE_TRACE, n_requests=10_000)
        lpn = tr["lpn"].reshape(-1)
        assert (lpn[:10_000] >= 0).all()


class TestSweep:
    def _spec(self, **kw):
        d = dict(
            scenario="read_disturb_hammer",
            n_requests=4_000,
            policies=(geometry.BASELINE, geometry.RARO),
            initial_pe=(166, 833),
            seeds=(0, 1),
            base=TINY,
        )
        d.update(kw)
        return sweep.SweepSpec(**d)

    def test_expand_cross_product(self):
        spec = self._spec(r2_override=(-1, 7))
        runs = sweep.expand(spec)
        assert len(runs) == spec.n_runs() == 16
        assert len({r.tag() for r in runs}) == 16

    def test_grid_results_and_tail_ordering(self):
        res = sweep.run_sweep(self._spec())
        assert len(res) == 8
        for r in res:
            assert r["read_lat_p50_us"] <= r["read_lat_p99_us"]
            assert r["reads"] == 4_000
        # batched run == unbatched run: baseline pe833 seed0 via engine.run
        cfg = geometry.tiny_config(policy=geometry.BASELINE, initial_pe=833)
        tr = registry.build("read_disturb_hammer", TINY, 4_000, seed=0)
        s, _ = engine.run(cfg, tr)
        single = engine.summarize(s, cfg)
        batched = [r for r in res if r["run"]["tag"]
                   == "read_disturb_hammer_baseline_pe833_seed0"][0]
        np.testing.assert_allclose(
            batched["mean_read_latency_us"], single["mean_read_latency_us"],
            rtol=1e-4,
        )

    def test_raro_beats_baseline_p99_on_hammer(self):
        res = sweep.run_sweep(self._spec(seeds=(0,)))
        by = {r["run"]["tag"]: r for r in res}
        for pe in (166, 833):
            b = by[f"read_disturb_hammer_baseline_pe{pe}_seed0"]
            r = by[f"read_disturb_hammer_raro_pe{pe}_seed0"]
            assert r["read_lat_p99_us"] < b["read_lat_p99_us"], pe
            assert r["mean_read_latency_us"] < b["mean_read_latency_us"], pe

    def test_r2_override_changes_behavior(self):
        spec = self._spec(policies=(geometry.RARO,), initial_pe=(833,),
                          seeds=(0,), r2_override=(-1, 2))
        res = sweep.run_sweep(spec)
        migrated = [r["migrated_pages"] for r in res]
        # aggressive R2=2 must migrate at least as much as the stage schedule
        assert migrated[1] >= migrated[0]

    def test_artifacts_roundtrip(self, tmp_path):
        res = sweep.run_sweep(self._spec(policies=(geometry.RARO,),
                                         initial_pe=(500,), seeds=(0,)))
        paths = sweep.write_artifacts(res, tmp_path)
        assert len(paths) == 1 and paths[0].name.startswith("BENCH_sweep_")
        doc = json.loads(paths[0].read_text())
        assert doc["run"]["policy"] == "raro"
        assert doc["metrics"]["read_lat_p99_us"] == pytest.approx(
            res[0]["read_lat_p99_us"])
        names = [r[0] for r in doc["rows"]]
        assert any(n.endswith("read_lat_p99_us") for n in names)

    def test_seed_invariant_scenario_warns_on_multi_seed(self):
        spec = self._spec(scenario="msr_sample", n_requests=1_000,
                          policies=(geometry.BASELINE,), initial_pe=(166,),
                          seeds=(0, 1))
        with pytest.warns(UserWarning, match="deterministic w.r.t. seed"):
            sweep.run_sweep(spec)

    def test_msr_scenario_usable_from_sweep(self):
        spec = self._spec(scenario="msr_sample", n_requests=2_000,
                          policies=(geometry.RARO,), initial_pe=(500,),
                          seeds=(0,))
        res = sweep.run_sweep(spec)
        assert len(res) == 1
        assert res[0]["writes"] > 0
        assert res[0]["run"]["scenario"] == "msr_sample"
