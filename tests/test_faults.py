"""Fault-injection and recovery tests (DESIGN.md §2D).

Three things are pinned here:

  1. Bit-identity of the no-fault path — the fault subsystem must be free
     when off, both statically (no fault ops traced) and for a *traced*
     zero-rate run sharing a compiled program with faulty runs.
  2. Each fault class actually fires and recovers correctly: uncorrectable
     reads pay the ECC penalty, failed programs re-place through the normal
     allocator, failed erases retire blocks into the bad-block map — and
     ``state.check_invariants`` holds throughout (mapping bijection, free
     counts, bad-block accounting).
  3. Sweep robustness: checkpointed groups resume deterministically
     (killed-then-resumed == uninterrupted, bit for bit) and stale
     checkpoints are ignored rather than trusted.
"""

import json

import jax
import numpy as np
import pytest
from hyp_fallback import given, settings
from hyp_fallback import st as st_h

from repro.core import faults
from repro.experiments import sweep
from repro.ssdsim import engine, geometry, state as st, workload

TINY = geometry.tiny_config()


def _mixed(cfg, n=4_096, seed=1, read_frac=0.7, write_theta=None):
    return workload.mixed_trace(cfg, n, 1.2, read_frac=read_frac, seed=seed,
                                write_theta=write_theta)


# --------------------------- parameter plumbing ----------------------------


class TestParams:
    def test_defaults_are_statically_off(self):
        assert not TINY.faults_enabled
        assert faults.params_for(TINY) is None
        # knobs without fault fields don't arm the model either
        from repro.ssdsim import policies
        k = policies.RunKnobs(r1=1, r2_override=-1, initial_pe=500)
        assert faults.params_for(TINY, k) is None

    def test_config_path_arms(self):
        cfg = geometry.tiny_config(prog_fail_rate=0.1)
        assert cfg.faults_enabled
        p = faults.params_for(cfg)
        assert float(p.prog_fail_rate) == pytest.approx(0.1)
        assert int(p.max_read_retries) == -1

    def test_knobs_path_wins_over_config(self):
        from repro.ssdsim import policies
        cfg = geometry.tiny_config(prog_fail_rate=0.1)
        k = policies.RunKnobs(
            r1=1, r2_override=-1, initial_pe=500,
            prog_fail_rate=np.float32(0.25), erase_fail_rate=np.float32(0.0),
            max_read_retries=np.int32(4), fault_seed=np.int32(7),
        )
        p = faults.params_for(cfg, k)
        assert float(p.prog_fail_rate) == pytest.approx(0.25)
        assert int(p.max_read_retries) == 4

    def test_draws_uniform_deterministic_and_stream_separated(self):
        ids = np.arange(4_096, dtype=np.int32)
        pe = np.full_like(ids, 500)
        u1 = np.asarray(faults.uniform01(ids, pe, 1, faults.STREAM_PROG))
        u2 = np.asarray(faults.uniform01(ids, pe, 1, faults.STREAM_PROG))
        ue = np.asarray(faults.uniform01(ids, pe, 1, faults.STREAM_ERASE))
        assert ((u1 > 0.0) & (u1 < 1.0)).all()
        np.testing.assert_array_equal(u1, u2)  # stateless + reproducible
        assert (u1 != ue).mean() > 0.99  # PROG and ERASE never share a draw
        # roughly uniform: each decile within a few points of 10%
        hist, _ = np.histogram(u1, bins=10, range=(0.0, 1.0))
        assert (np.abs(hist / len(u1) - 0.1) < 0.03).all()


# ------------------------- no-fault bit identity ---------------------------


class TestZeroFaultBitIdentity:
    def test_traced_zero_rates_match_knob_free_program(self):
        """The fault ops traced into the sweep program (rates 0.0, budget
        -1) must reproduce the knob-free program's summaries bit for bit —
        the property that lets one compiled grid mix fault-free and faulty
        runs."""
        base = dict(
            scenario="write_burst_then_read", n_requests=2_048,
            policies=(geometry.BASELINE, geometry.RARO),
            initial_pe=(833,), seeds=(0,), base=TINY,
        )
        plain = sweep.run_sweep(sweep.SweepSpec(**base))
        # fault_seed != default flips faults_on() -> the fault ops are
        # traced and the knobs ride the batch, but no draw can fire
        armed = sweep.run_sweep(sweep.SweepSpec(**base, fault_seed=(1,)))
        assert len(plain) == len(armed)
        for a, b in zip(plain, armed):
            assert a["run"]["policy"] == b["run"]["policy"]
            for key, val in a.items():
                if key == "run":
                    continue
                np.testing.assert_array_equal(
                    np.asarray(val), np.asarray(b[key]),
                    err_msg=f"summary key {key!r} diverged with zero-rate "
                            f"fault knobs traced in",
                )

    def test_fault_counters_zero_when_off(self):
        s, _ = engine.run(TINY, _mixed(TINY))
        for leaf in (s.n_uncorrectable, s.n_prog_fails, s.n_erase_fails,
                     s.n_dropped_writes, s.bad_count):
            assert float(leaf) == 0.0


# ------------------------- the three fault classes -------------------------


class TestUncorrectableReads:
    @pytest.fixture(scope="class")
    def runs(self):
        mk = lambda **kw: geometry.tiny_config(  # noqa: E731
            policy=geometry.BASELINE, initial_pe=900, **kw)
        cfg = mk(max_read_retries=2, fault_seed=1)
        tr = workload.zipf_read_trace(cfg, 8_192, 1.2, seed=1)
        s, _ = engine.run(cfg, tr)
        s0, _ = engine.run(mk(), tr)  # same trace, unlimited retries
        return cfg, s, s0

    def test_uncorrectables_fire_and_invariants_hold(self, runs):
        cfg, s, _ = runs
        assert float(s.n_uncorrectable) > 0
        st.check_invariants(s, cfg)

    def test_recovery_penalty_shows_in_latency(self, runs):
        cfg, s, s0 = runs
        assert float(s.n_reads) == float(s0.n_reads)  # no read is dropped
        mean = float(s.svc_sum_ms) / float(s.n_reads)
        mean0 = float(s0.svc_sum_ms) / float(s0.n_reads)
        # worn QLC at pe=900 retries far past a budget of 2: most reads pay
        # the 5 ms recovery penalty (partly offset by the collapsed retries)
        assert mean > 2.0 * mean0

    def test_budget_collapses_retry_count(self, runs):
        cfg, s, s0 = runs
        # an uncorrectable read burns exactly the budget, never more
        assert float(s.n_retries) < float(s0.n_retries)


class TestProgramFailures:
    @pytest.fixture(scope="class")
    def runs(self):
        cfg = geometry.tiny_config(policy=geometry.BASELINE, initial_pe=500,
                                   prog_fail_rate=0.05, fault_seed=1)
        tr = _mixed(cfg)
        s, _ = engine.run(cfg, tr)
        s0, _ = engine.run(geometry.tiny_config(
            policy=geometry.BASELINE, initial_pe=500), tr)
        return cfg, s, s0

    def test_prog_fails_fire_and_invariants_hold(self, runs):
        cfg, s, _ = runs
        assert float(s.n_prog_fails) > 0
        st.check_invariants(s, cfg)

    def test_failed_programs_are_replaced_not_lost(self, runs):
        cfg, s, s0 = runs
        # every write the fault-free run completed still completes: the
        # failed page re-places through ftl._place_pages onto a fresh block
        assert float(s.n_writes) == float(s0.n_writes)
        assert float(s.n_dropped_writes) == 0.0
        assert (np.asarray(s.l2p) >= 0).all()


class TestEraseFailures:
    @pytest.fixture(scope="class")
    def run(self):
        # the engine-bench gc_pressure geometry: tiny free pool + write-heavy
        # Zipf overwrites, so GC erases fire on nearly every chunk
        cfg = geometry.tiny_config(
            policy=geometry.BASELINE, initial_pe=500, n_logical=2_944,
            gc_free_threshold=18, gc_victims_per_pass=4,
            erase_fail_rate=0.1, fault_seed=1,
        )
        tr = _mixed(cfg, n=16_384, read_frac=0.1, write_theta=2.0)
        s, _ = engine.run(cfg, tr)
        return cfg, s

    def test_blocks_retire_into_bad_map(self, run):
        cfg, s = run
        assert float(s.bad_count) > 0
        bs = np.asarray(s.block_state)
        bad = np.asarray(s.block_bad)
        np.testing.assert_array_equal(bad, bs == st.BAD)
        assert float(s.n_erase_fails) == float(s.bad_count)
        # retired blocks hold nothing and are excluded from usable capacity
        assert (np.asarray(s.block_valid)[bad] == 0).all()
        st.check_invariants(s, cfg)

    def test_erase_attempts_include_failures(self, run):
        cfg, s = run
        assert float(s.n_erases) > float(s.n_erase_fails)


class TestGracefulDegradation:
    def test_alloc_exhaustion_stalls_instead_of_corrupting(self):
        # fault_storm shape on a worn tiny device: concentrated overwrites
        # outrun the free pool, so some writes find no open slot. They must
        # stall (counted in n_dropped_writes) and leave the state coherent.
        cfg = geometry.tiny_config(
            policy=geometry.BASELINE, initial_pe=900,
            max_read_retries=6, erase_fail_rate=0.05, fault_seed=1,
        )
        tr = _mixed(cfg, read_frac=0.3, write_theta=2.0, seed=0)
        s, _ = engine.run(cfg, tr)
        assert float(s.n_dropped_writes) > 0
        st.check_invariants(s, cfg)


# --------------------- property test: random schedules ---------------------


class TestFaultScheduleProperty:
    R = 3  # static batch width -> one compile reused across examples

    @settings(max_examples=8, deadline=None)
    @given(
        pfail=st_h.lists(st_h.floats(0.0, 0.3), min_size=R, max_size=R),
        efail=st_h.lists(st_h.floats(0.0, 0.3), min_size=R, max_size=R),
        mrr=st_h.lists(st_h.integers(-1, 8), min_size=R, max_size=R),
        seed=st_h.integers(0, 2**16),
    )
    def test_random_fault_schedules_never_break_invariants(
            self, pfail, efail, mrr, seed):
        """Any mix of fault rates / retry budgets / seeds across a batched
        run axis keeps every per-run state consistent: mapping bijection,
        exact free counts, bad-block accounting."""
        from repro.ssdsim import policies

        cfg = geometry.tiny_config(policy=geometry.RARO)
        tr = _mixed(cfg, n=2_048, read_frac=0.5, write_theta=2.0)
        lpns = np.broadcast_to(np.asarray(tr["lpn"], np.int32),
                               (self.R, *tr["lpn"].shape))
        ops = np.broadcast_to(np.asarray(tr["op"], np.int32),
                              (self.R, *tr["op"].shape))
        knobs = policies.RunKnobs(
            r1=np.full(self.R, cfg.r1, np.int32),
            r2_override=np.full(self.R, -1, np.int32),
            initial_pe=np.full(self.R, 833, np.int32),
            prog_fail_rate=np.asarray(pfail, np.float32),
            erase_fail_rate=np.asarray(efail, np.float32),
            max_read_retries=np.asarray(mrr, np.int32),
            fault_seed=np.asarray([seed + i for i in range(self.R)], np.int32),
        )
        states = sweep._sweep_jit(cfg, lpns, ops, True, knobs, None)
        states = jax.device_get(states)
        for i in range(self.R):
            s = sweep._take_run(states, i)
            st.check_invariants(s, cfg)
            assert float(s.bad_count) == float(s.n_erase_fails)


# ------------------------ checkpointed sweep resume ------------------------


def _fault_spec(**kw):
    d = dict(
        scenario="fault_storm", n_requests=2_048,
        policies=(geometry.BASELINE, geometry.RARO),
        initial_pe=(900,), seeds=(0,),
        prog_fail_rate=(0.0, 0.02), erase_fail_rate=(0.05,),
        max_read_retries=(6,), base=TINY,
    )
    d.update(kw)
    return sweep.SweepSpec(**d)


class TestSweepResume:
    @pytest.fixture(scope="class")
    def baseline(self):
        return sweep.run_sweep(_fault_spec())

    def test_checkpointing_changes_nothing(self, baseline, tmp_path):
        res = sweep.run_sweep(_fault_spec(), resume_dir=tmp_path)
        sweep.assert_results_identical(baseline, res)
        assert sorted(p.name for p in tmp_path.glob("ckpt_*.json")) == [
            "ckpt_fault_storm_baseline.json", "ckpt_fault_storm_raro.json"]

    def test_full_resume_is_identical(self, baseline, tmp_path):
        spec = _fault_spec()
        sweep.run_sweep(spec, resume_dir=tmp_path)
        # every group cached: the rerun must not recompute anything and the
        # merged results must match the uninterrupted run bit for bit
        res = sweep.run_sweep(spec, resume_dir=tmp_path)
        sweep.assert_results_identical(baseline, res)

    def test_partial_resume_is_identical(self, baseline, tmp_path):
        """Simulates a sweep killed after one policy group completed: only
        the missing group reruns and the merged results are unchanged."""
        spec = _fault_spec()
        sweep.run_sweep(spec, resume_dir=tmp_path)
        (tmp_path / "ckpt_fault_storm_raro.json").unlink()
        res = sweep.run_sweep(spec, resume_dir=tmp_path)
        sweep.assert_results_identical(baseline, res)

    def test_stale_checkpoint_is_ignored(self, baseline, tmp_path):
        spec = _fault_spec()
        sweep.run_sweep(spec, resume_dir=tmp_path)
        p = tmp_path / "ckpt_fault_storm_baseline.json"
        doc = json.loads(p.read_text())
        doc["n_requests"] = 999  # pretend it came from a different sweep
        p.write_text(json.dumps(doc))
        res = sweep.run_sweep(spec, resume_dir=tmp_path)
        sweep.assert_results_identical(baseline, res)


# ----------------------- device-count clamp satellites ---------------------


class TestDeviceClamp:
    def test_resolve_devices_clamps_and_warns(self):
        avail = len(jax.devices())
        with pytest.warns(UserWarning, match="clamping"):
            devs = sweep.resolve_devices(avail + 99)
        assert len(devs) == avail

    def test_fake_host_devices_clamps_to_cores(self, monkeypatch):
        import os

        from repro import hostdev

        monkeypatch.setattr(os, "cpu_count", lambda: 2)
        monkeypatch.setenv("XLA_FLAGS", "")
        with pytest.warns(UserWarning, match="clamping"):
            hostdev.fake_host_devices(64)
        assert os.environ["XLA_FLAGS"].endswith(
            "--xla_force_host_platform_device_count=2")
        with pytest.raises(ValueError):
            hostdev.fake_host_devices(-3)
