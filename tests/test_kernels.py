"""Per-kernel validation: sweep shapes/dtypes, assert_allclose against the
pure-jnp ref.py oracles (kernels run in interpret mode on CPU; TPU is the
compilation target)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_fallback import given, settings, st

from repro.core import modes
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.quant_page.ops import quant_pages
from repro.kernels.quant_page.ref import quant_pages_ref
from repro.kernels.tiered_attention.ops import tiered_decode_attention
from repro.kernels.tiered_attention.ref import tiered_decode_attention_ref
from repro.kvcache import paged, tiers


class TestFlashAttention:
    SHAPES = [
        # (B, Sq, Sk, H, Hk, D, causal)
        (2, 64, 64, 4, 4, 32, True),
        (1, 128, 128, 8, 2, 64, True),  # GQA
        (2, 33, 95, 4, 1, 16, False),  # MQA + ragged padding
        (1, 257, 300, 2, 2, 128, True),  # odd sizes, MXU-width head
    ]

    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, shape, dtype):
        b, sq, sk, h, hk, d, causal = shape
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, sq, h, d), dtype)
        k = jax.random.normal(ks[1], (b, sk, hk, d), dtype)
        v = jax.random.normal(ks[2], (b, sk, hk, d), dtype)
        o = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
        r = flash_attention_ref(q, k, v, causal=causal)
        tol = 2e-6 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(o, np.float32), np.asarray(r, np.float32), atol=tol, rtol=tol
        )

    @given(
        sq=st.integers(1, 70),
        sk=st.integers(1, 70),
        h=st.sampled_from([1, 2, 4]),
        g=st.sampled_from([1, 2]),
        causal=st.booleans(),
    )
    @settings(max_examples=12, deadline=None)
    def test_property_shapes(self, sq, sk, h, g, causal):
        hk = h  # h query heads per group g -> total q heads = h * g
        ks = jax.random.split(jax.random.PRNGKey(sq * 71 + sk), 3)
        q = jax.random.normal(ks[0], (1, sq, h * g, 16), jnp.float32)
        k = jax.random.normal(ks[1], (1, sk, hk, 16), jnp.float32)
        v = jax.random.normal(ks[2], (1, sk, hk, 16), jnp.float32)
        o = flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
        r = flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=3e-6, rtol=3e-6)


def _build_cache(key, b, mp, p, hk, d, steps, mixed=True):
    cfg = paged.CacheConfig(n_seqs=b, max_pages=mp, page_size=p, n_kv_heads=hk,
                            head_dim=d, pool_pages=(mp * b, mp * b, mp * b),
                            migrate_per_step=2)
    rcfg = tiers.RAROConfig()
    c = paged.init(cfg, jnp.float32)
    for t in range(steps):
        k1 = jax.random.normal(jax.random.fold_in(key, 2 * t), (b, hk, d)) * 0.5
        v1 = jax.random.normal(jax.random.fold_in(key, 2 * t + 1), (b, hk, d)) * 0.5
        ct = tiers.commit_tier(c, cfg, rcfg)
        c = paged.append(c, cfg, k1, v1, ct)
        if mixed and t % 3 == 0:
            masses = jax.random.uniform(jax.random.fold_in(key, 900 + t), (b, mp)) * 0.05
            c, _ = tiers.raro_step(c, cfg, rcfg, masses)
    return cfg, c


class TestTieredAttention:
    @pytest.mark.parametrize("shape", [
        # (B, MP, P, Hk, G, D, steps)
        (2, 6, 4, 2, 2, 16, 18),
        (1, 4, 8, 1, 4, 32, 25),
        (3, 8, 4, 4, 1, 64, 30),
    ])
    def test_matches_oracle(self, shape):
        b, mp, p, hk, g, d, steps = shape
        cfg, c = _build_cache(jax.random.PRNGKey(7), b, mp, p, hk, d, steps)
        q = jax.random.normal(jax.random.PRNGKey(11), (b, hk * g, d), jnp.float32)
        o, mass = tiered_decode_attention(q, c, cfg)
        o_r, mass_r = tiered_decode_attention_ref(q, c, cfg)
        np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(o_r),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(mass), np.asarray(mass_r), atol=1e-6)

    def test_mass_is_probability(self):
        cfg, c = _build_cache(jax.random.PRNGKey(3), 2, 6, 4, 2, 16, 20)
        q = jax.random.normal(jax.random.PRNGKey(5), (2, 4, 16), jnp.float32)
        _, mass = tiered_decode_attention(q, c, cfg)
        m = np.asarray(mass)
        assert (m >= -1e-6).all() and (m.sum(1) <= 1.0 + 1e-5).all()

    def test_all_tiers_exercised(self):
        cfg, c = _build_cache(jax.random.PRNGKey(7), 2, 6, 4, 2, 16, 24)
        tiers_present = set(np.asarray(c.tier).ravel()) - {-1}
        assert len(tiers_present) >= 2, "cache should hold mixed tiers"


class TestQuantPage:
    @pytest.mark.parametrize("tier", [modes.TIER_INT8, modes.TIER_INT4])
    @pytest.mark.parametrize("shape", [(4, 16, 4, 32), (2, 64, 2, 128), (1, 8, 8, 64)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, tier, shape, dtype):
        from repro.kvcache import quant

        x = jax.random.normal(jax.random.PRNGKey(0), shape, dtype)
        q, s, e = quant_pages(x, tier=tier)
        q_r, s_r, e_r = quant_pages_ref(x, tier=tier)
        if tier == modes.TIER_INT4:  # compare unpacked nibbles
            q, q_r = quant.unpack_int4(q), quant.unpack_int4(q_r)
        # scales may differ by 1 ulp (reduction order), so integer codes may
        # differ by at most 1 at exact rounding ties
        dq = np.abs(np.asarray(q, np.int32) - np.asarray(q_r, np.int32))
        assert dq.max() <= 1 and (dq != 0).mean() < 0.01  # bf16/int4 hits many exact .5 ties
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_r), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(e), np.asarray(e_r), rtol=1e-4, atol=1e-6)
        # dequantized values agree within one quantization step
        step = np.asarray(s_r).max()
        xd_k = np.asarray(q, np.float32) * np.asarray(s)[:, None, :, None]
        xd_r = np.asarray(q_r, np.float32) * np.asarray(s_r)[:, None, :, None]
        np.testing.assert_allclose(xd_k, xd_r, atol=1.01 * step)

    def test_error_ordering(self):
        # int4 must be lossier than int8 — the RBER ordering of the tiers.
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 4, 32), jnp.float32)
        _, _, e8 = quant_pages(x, tier=modes.TIER_INT8)
        _, _, e4 = quant_pages(x, tier=modes.TIER_INT4)
        assert (np.asarray(e4) > np.asarray(e8)).all()
