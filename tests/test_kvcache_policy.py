"""Behavioral tests for the RARO KV-tier controller (Layer B): the policy
must do on KV pages what the paper's FTL does on flash blocks."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import modes
from repro.kvcache import paged, tiers


def _cfg(**kw):
    base = dict(n_seqs=2, max_pages=8, page_size=4, n_kv_heads=2, head_dim=8,
                pool_pages=(8, 8, 64), migrate_per_step=4)
    base.update(kw)
    return paged.CacheConfig(**base)


def _fill(cfg, rcfg, n_tokens, key=0, masses_fn=None):
    c = paged.init(cfg, jnp.float32)
    k = jax.random.PRNGKey(key)
    for t in range(n_tokens):
        k1 = jax.random.normal(jax.random.fold_in(k, 2 * t), (cfg.n_seqs, cfg.n_kv_heads, cfg.head_dim))
        v1 = jax.random.normal(jax.random.fold_in(k, 2 * t + 1), (cfg.n_seqs, cfg.n_kv_heads, cfg.head_dim))
        ct = tiers.commit_tier(c, cfg, rcfg)
        c = paged.append(c, cfg, k1, v1, ct)
        masses = (masses_fn(t) if masses_fn
                  else jnp.zeros((cfg.n_seqs, cfg.max_pages)))
        c, _ = tiers.raro_step(c, cfg, rcfg, masses)
    return c


def test_cold_pages_stay_dense():
    """No attention mass -> everything commits and stays at int4 (QLC)."""
    cfg = _cfg()
    c = _fill(cfg, tiers.RAROConfig(), 24)
    t = np.asarray(c.tier)
    committed = t[t >= 0]
    assert (committed == modes.TIER_INT4).all()


def test_hot_pages_get_promoted():
    """Concentrated attention on page 0 -> it is promoted out of int4."""
    cfg = _cfg()
    rcfg = tiers.RAROConfig()

    def masses(t):
        m = np.zeros((2, 8), np.float32)
        m[:, 0] = 0.6  # heavy attention on the first page
        return jnp.asarray(m)

    c = _fill(cfg, rcfg, 24, masses_fn=masses)
    t = np.asarray(c.tier)
    assert (t[:, 0] == modes.TIER_BF16).all(), t[:, 0]
    # later (cold) pages stay dense
    assert (t[:, 2][t[:, 2] >= 0] == modes.TIER_INT4).all()


def test_disabled_controller_is_static_int4():
    cfg = _cfg()
    rcfg = tiers.RAROConfig(enabled=False)

    def masses(t):
        return jnp.full((2, 8), 0.4)

    c = _fill(cfg, rcfg, 24, masses_fn=masses)
    t = np.asarray(c.tier)
    assert (t[t >= 0] == modes.TIER_INT4).all()


def test_retry_estimate_grows_with_reads_and_density():
    cfg = _cfg()
    c = _fill(cfg, tiers.RAROConfig(), 16)
    lo = tiers.page_retry_estimate(c, tiers.RAROConfig())
    c2 = c._replace(reads=c.reads + 50.0)
    hi = tiers.page_retry_estimate(c2, tiers.RAROConfig())
    t = np.asarray(c.tier)
    sel = t >= 0
    assert (np.asarray(hi)[sel] >= np.asarray(lo)[sel]).all()
    assert np.asarray(hi)[sel].max() > 0


def test_elastic_recovery_demotes_under_pressure():
    """Fill the bf16 pool, cool everything -> demotions kick in."""
    cfg = _cfg(pool_pages=(2, 4, 64), high_watermark=0.4)
    # fast heat decay so pages actually go COLD within the test horizon
    from repro.core import hotness

    rcfg = tiers.RAROConfig(heat=hotness.HeatConfig(decay=0.6, hot_thresh=0.08,
                                                    warm_thresh=0.02))
    hot_then_cold = [0.6] * 12 + [0.0] * 24

    def masses(t):
        m = np.zeros((2, 8), np.float32)
        m[:, :2] = hot_then_cold[min(t, len(hot_then_cold) - 1)]
        return jnp.asarray(m)

    c = _fill(cfg, rcfg, 36, masses_fn=masses)
    occ0 = float(1.0 - c.free[0].mean())
    # bf16 pool pressure relieved by demotion of cooled pages
    assert occ0 <= 0.5 + 1e-6, occ0


def test_capacity_accounting_matches_tiers():
    cfg = _cfg()
    c = _fill(cfg, tiers.RAROConfig(), 24)
    p, hk, dh = cfg.page_size, cfg.n_kv_heads, cfg.head_dim
    t = np.asarray(c.tier)
    per = {0: 2 * p * hk * dh * 2, 1: 2 * p * hk * dh, 2: p * hk * dh}
    expect = sum(per[int(x)] for x in t[t >= 0])
    assert paged.memory_bytes(c, cfg) == expect
