"""Metrics-schema registry tests (DESIGN.md §2E).

``ssdsim.metrics_schema`` is the single source of truth for metric names,
units and descriptions: ``engine.summarize`` may only emit keys registered
there, and the sweep CSV unit map is the registry's scalar subset rather
than a hand-maintained copy. Also pins the geometry alias deprecations
(``lun_of_block`` / ``channel_of_lun``): warn once, delegate exactly, and no
production module may still call them.
"""

import dataclasses
import re
import warnings
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.experiments import registry, sweep
from repro.ssdsim import engine, geometry, metrics_schema, obs

SRC = Path(__file__).resolve().parent.parent / "src" / "repro"


def _summary(cfg):
    tr = registry.build("mixed", cfg, 8 * cfg.chunk, seed=0, read_frac=0.5)
    s, _ = engine.run(cfg, tr)
    return engine.summarize(jax.device_get(s), cfg)


class TestSchemaCoversSummarize:
    @pytest.mark.parametrize("level", obs.LEVELS)
    def test_summarize_keys_subset_of_schema(self, level):
        cfg = geometry.tiny_config(obs_level=level)
        if level == "full":
            cfg = dataclasses.replace(cfg, obs_event_capacity=256)
        m = _summary(cfg)
        unknown = set(m) - set(metrics_schema.SCHEMA)
        assert not unknown, f"summarize emits unregistered metrics: {unknown}"

    def test_faults_armed_keys_subset_of_schema(self):
        cfg = geometry.tiny_config(prog_fail_rate=0.02, erase_fail_rate=0.05,
                                   max_read_retries=4, fault_seed=1)
        m = _summary(cfg)
        assert set(m) <= set(metrics_schema.SCHEMA)

    def test_scalar_flags_match_reality(self):
        cfg = geometry.tiny_config(obs_level="full", obs_event_capacity=256)
        m = _summary(cfg)
        for k, v in m.items():
            if metrics_schema.SCHEMA[k].scalar:
                assert np.isscalar(v) or isinstance(v, (int, float)), (
                    f"{k} registered scalar but summarize emitted {type(v)}")
            else:
                assert not isinstance(v, (int, float)), (
                    f"{k} registered non-scalar but summarize emitted {type(v)}")

    def test_endurance_metrics_registered_with_units(self):
        u = metrics_schema.units()
        assert u["waf"] == "ratio"
        assert u["lifetime_years"] == "years"
        for k in ("user_pages", "reloc_pages", "waf", "pe_mean",
                  "pe_variance", "pe_max", "tbw_gib", "dwpd",
                  "lifetime_years"):
            assert k in u
            assert metrics_schema.describe(k).description

    def test_every_metric_documented(self):
        for k, m in metrics_schema.SCHEMA.items():
            assert m.unit, f"{k} has no unit"
            assert m.description, f"{k} has no description"


class TestSweepUsesRegistry:
    def test_row_units_is_the_scalar_subset(self):
        ru = metrics_schema.row_units()
        assert ru == {k: m.unit for k, m in metrics_schema.SCHEMA.items()
                      if m.scalar}

    def test_sweep_row_units_come_from_registry(self):
        assert sweep._ROW_UNITS == metrics_schema.row_units()


class TestGeometryAliasDeprecation:
    def _reset(self):
        geometry._ALIAS_WARNED.clear()

    def test_lun_of_block_warns_once_and_delegates(self):
        cfg = geometry.tiny_config()
        self._reset()
        blocks = np.arange(8)
        with pytest.warns(DeprecationWarning, match="lun_of_block"):
            got = cfg.lun_of_block(blocks)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(cfg.die_of_block(blocks)))
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            cfg.lun_of_block(blocks)
        assert not [r for r in rec if issubclass(r.category, DeprecationWarning)]

    def test_channel_of_lun_warns_once_and_delegates(self):
        cfg = geometry.tiny_config()
        self._reset()
        dies = np.arange(cfg.n_dies)
        with pytest.warns(DeprecationWarning, match="channel_of_lun"):
            got = cfg.channel_of_lun(dies)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(cfg.channel_of_die(dies)))

    def test_no_production_callers_of_deprecated_aliases(self):
        # grep-style sweep over src/: only geometry.py (the definitions) may
        # mention the deprecated names
        pat = re.compile(r"\b(lun_of_block|channel_of_lun)\b")
        offenders = []
        for p in sorted(SRC.rglob("*.py")):
            if p.name == "geometry.py":
                continue
            for i, line in enumerate(p.read_text().splitlines(), 1):
                if pat.search(line):
                    offenders.append(f"{p.relative_to(SRC)}:{i}: {line.strip()}")
        assert not offenders, "\n".join(offenders)
