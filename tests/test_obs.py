"""Observability layer tests (DESIGN.md §7.4): latency attribution,
conversion event ring, windowed time series, exporters."""

import json

import jax
import numpy as np
import pytest
from hyp_fallback import given, settings
from hyp_fallback import st as st_h

from repro.core import modes
from repro.ssdsim import engine, geometry, obs, state as st, trace_export, workload


def _full_cfg(**kw):
    base = dict(policy=geometry.RARO, initial_pe=500, obs_level="full",
                obs_event_capacity=4096, obs_windows=32, obs_window_ms=5.0)
    base.update(kw)
    return geometry.tiny_config(**base)


@pytest.fixture(scope="module")
def mixed_run():
    """One tiny mixed closed-loop run with every instrument on."""
    cfg = _full_cfg()
    tr = workload.mixed_trace(cfg, 16 * cfg.chunk, theta=1.0, read_frac=0.7,
                              seed=3)
    s, _ = engine.run(cfg, tr)
    return cfg, jax.device_get(s)


@pytest.fixture(scope="module")
def open_run():
    """Same workload under the open-loop arrival model (queue component)."""
    cfg = _full_cfg()
    tr = workload.mixed_trace(cfg, 16 * cfg.chunk, theta=1.0, read_frac=0.7,
                              seed=3, arrival_rate=8000.0)
    s, _ = engine.run(cfg, tr)
    return cfg, jax.device_get(s)


@pytest.fixture(scope="module")
def lattice_run():
    """Open-loop run under the lattice channel model on a single shared bus
    (1 channel x 4 dies): the chan_wait component actually fires."""
    cfg = _full_cfg(n_channels=1, luns_per_channel=4, chan_model="lattice")
    tr = workload.mixed_trace(cfg, 16 * cfg.chunk, theta=1.0, read_frac=0.9,
                              seed=3, arrival_rate=30000.0)
    s, _ = engine.run(cfg, tr)
    return cfg, jax.device_get(s)


class TestLatencyAttribution:
    def test_per_mode_hist_sums_to_lat_hist_bit_exact(self, mixed_run):
        cfg, s = mixed_run
        assert np.array_equal(np.asarray(s.obs_lat_mode).sum(axis=0),
                              np.asarray(s.lat_hist))

    def test_open_loop_hist_sums_bit_exact(self, open_run):
        cfg, s = open_run
        assert np.array_equal(np.asarray(s.obs_lat_mode).sum(axis=0),
                              np.asarray(s.lat_hist))

    def test_mode_counts_cover_all_reads(self, mixed_run):
        cfg, s = mixed_run
        assert np.asarray(s.obs_lat_mode).sum() == float(s.n_reads) > 0

    def test_components_sum_to_recorded_latency(self, open_run):
        """Per (mode, bin), the four component µs together reconstruct the
        total recorded latency mass binned there (queue + sense + retry
        penalty + transfer is the recorded latency, by construction)."""
        cfg, s = open_run
        comp = np.asarray(s.obs_lat_comp, np.float64)
        counts = np.asarray(s.obs_lat_mode, np.float64)
        total_us = comp.sum(axis=1)  # (modes, bins)
        from repro.ssdsim import telemetry
        lo = telemetry.bin_edges_us()[:-1]
        hi = telemetry.bin_edges_us()[1:]
        # mass in each bin must lie within the bin's edge bounds x count
        # (first/last bins are clipped, so only check the interior)
        inner = slice(1, telemetry.N_LAT_BINS - 1)
        assert (
            total_us[:, inner] >= counts[:, inner] * lo[inner] * 0.999
        ).all()
        assert (
            total_us[:, inner] <= counts[:, inner] * hi[inner] * 1.001
        ).all()

    def test_closed_loop_queue_component_is_zero(self, mixed_run):
        cfg, s = mixed_run
        assert np.asarray(s.obs_lat_comp)[:, obs.COMP_QUEUE].sum() == 0.0

    def test_open_loop_queue_component_positive(self, open_run):
        cfg, s = open_run
        assert np.asarray(s.obs_lat_comp)[:, obs.COMP_QUEUE].sum() > 0.0

    def test_legacy_chan_wait_component_is_zero(self, open_run):
        """Under chan_model="legacy" transfer never queues, so the
        chan_wait component carries no mass (closed-loop likewise)."""
        cfg, s = open_run
        assert np.asarray(s.obs_lat_comp)[:, obs.COMP_CHANWAIT].sum() == 0.0

    def test_closed_loop_chan_wait_component_is_zero(self, mixed_run):
        cfg, s = mixed_run
        assert np.asarray(s.obs_lat_comp)[:, obs.COMP_CHANWAIT].sum() == 0.0

    def test_lattice_chan_wait_component_positive(self, lattice_run):
        """4 dies funneling into one bus under offered load: some reads
        must wait for the channel, and the wait is attributed."""
        cfg, s = lattice_run
        assert np.asarray(s.obs_lat_comp)[:, obs.COMP_CHANWAIT].sum() > 0.0

    def test_lattice_hist_sums_bit_exact(self, lattice_run):
        cfg, s = lattice_run
        assert np.array_equal(np.asarray(s.obs_lat_mode).sum(axis=0),
                              np.asarray(s.lat_hist))

    def test_lattice_components_sum_to_recorded_latency(self, lattice_run):
        """The five components (queue + sense + retry + chan_wait +
        transfer) still reconstruct the binned latency mass under the
        tandem model."""
        cfg, s = lattice_run
        comp = np.asarray(s.obs_lat_comp, np.float64)
        counts = np.asarray(s.obs_lat_mode, np.float64)
        total_us = comp.sum(axis=1)
        from repro.ssdsim import telemetry
        lo = telemetry.bin_edges_us()[:-1]
        hi = telemetry.bin_edges_us()[1:]
        inner = slice(1, telemetry.N_LAT_BINS - 1)
        assert (
            total_us[:, inner] >= counts[:, inner] * lo[inner] * 0.999
        ).all()
        assert (
            total_us[:, inner] <= counts[:, inner] * hi[inner] * 1.001
        ).all()

    def test_tail_attribution_shares_normalized(self, mixed_run):
        cfg, s = mixed_run
        att = obs.tail_attribution(s, cfg)
        for name in modes.MODE_NAMES:
            shares = att[name]["component_share"]
            if att[name]["tail_reads"] > 0:
                assert sum(shares.values()) == pytest.approx(1.0)


class TestEventRing:
    def test_decoded_matrix_equals_n_conversions(self, mixed_run):
        cfg, s = mixed_run
        records, total, dropped = obs.decode_events(s, cfg)
        assert dropped == 0
        mat = obs.event_conversion_matrix(records)
        assert np.array_equal(mat, np.asarray(s.n_conversions))
        assert mat.sum() > 0  # the run actually converted something

    def test_event_fields_in_range(self, mixed_run):
        cfg, s = mixed_run
        records, _, _ = obs.decode_events(s, cfg)
        for r in records:
            assert 0 <= r["from_mode"] < modes.N_MODES
            assert 0 <= r["to_mode"] < modes.N_MODES
            assert r["reason_name"] in obs.REASON_NAMES
            assert r["pages"] >= 0 and r["retry_est"] >= 0
            assert -1 <= r["block"] < cfg.n_blocks

    @settings(max_examples=25, deadline=None)
    @given(
        cap=st_h.integers(1, 9),
        batches=st_h.lists(
            st_h.lists(st_h.booleans(), min_size=1, max_size=6),
            min_size=0, max_size=8,
        ),
    )
    def test_overwrite_oldest_property(self, cap, batches):
        """The ring always holds the most recent ``min(total, cap)`` events
        in emission order, and the counter keeps the exact total."""
        cfg = geometry.tiny_config(obs_level="full", obs_event_capacity=cap)
        s = st.init_state(cfg)
        expected = []
        n = 0
        for mask in batches:
            k = len(mask)
            vals = np.arange(n, n + k, dtype=np.float32)
            s = obs.record_events(
                s, cfg, mask=np.asarray(mask), block=vals,
                from_mode=np.zeros(k), to_mode=np.ones(k),
                reason=obs.REASON_GC, retry_est=np.zeros(k), pages=vals,
            )
            expected += [float(v) for v, m in zip(vals, mask) if m]
            n += k
        records, total, dropped = obs.decode_events(s, cfg)
        assert total == len(expected)
        assert dropped == max(total - cap, 0)
        assert [r["pages"] for r in records] == [
            int(v) for v in expected[-min(total, cap):]
        ]

    def test_truncation_is_explicit(self):
        """Overflowing the ring keeps the true total and reports dropped."""
        cfg = _full_cfg(obs_event_capacity=8)
        tr = workload.mixed_trace(cfg, 16 * cfg.chunk, theta=1.0,
                                  read_frac=0.7, seed=3)
        s, _ = engine.run(cfg, tr)
        records, total, dropped = obs.decode_events(s, cfg)
        assert len(records) == min(total, 8)
        assert dropped == total - len(records)
        assert dropped > 0  # the mixed run emits more than 8 events


class TestTimeSeries:
    def test_series_sums_match_totals(self, mixed_run):
        cfg, s = mixed_run
        ts = obs.decode_timeseries(s, cfg)
        assert ts["reads"].sum() == float(s.n_reads)
        assert ts["retries"].sum() == float(s.n_retries)
        assert ts["writes"].sum() == float(s.n_writes)
        assert ts["conversions"].sum() == float(
            np.asarray(s.n_conversions).sum()
        )
        assert ts["erases"].sum() == float(s.n_erases)
        assert ts["migrated_pages"].sum() == float(s.n_migrated_pages)

    def test_open_loop_queue_series_positive(self, open_run):
        cfg, s = open_run
        ts = obs.decode_timeseries(s, cfg)
        assert ts["queue_ms"].sum() > 0
        assert ts["reads"].sum() == float(s.n_reads)


class TestChromeTrace:
    def test_schema(self, mixed_run, tmp_path):
        cfg, s = mixed_run
        p = trace_export.write_chrome_trace(s, cfg, tmp_path / "trace.json")
        doc = json.loads(p.read_text())
        evs = doc["traceEvents"]
        body = [e for e in evs if e["ph"] != "M"]
        assert body, "trace has no events"
        # required keys + sane values per phase
        for e in evs:
            assert e["ph"] in ("M", "X", "C")
            assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
            if e["ph"] == "X":
                assert e["ts"] >= 0 and e["dur"] > 0
                assert e["pid"] == trace_export.PID_FLASH
                assert 0 <= e["tid"] <= trace_export.policy_tid(cfg)
            if e["ph"] == "C":
                assert e["pid"] == trace_export.PID_TELEMETRY
        ts = [e["ts"] for e in body]
        assert all(a <= b for a, b in zip(ts, ts[1:])), "ts not monotone"
        # the lattice tracks: one per die, one bus per channel, plus the
        # page-granular policy track
        names = {
            e["args"]["name"] for e in evs
            if e["ph"] == "M" and e["name"] == "thread_name"
        }
        assert {
            f"die {d} (chan {cfg.channel_of_die(d)})"
            for d in range(cfg.n_dies)
        } <= names
        assert {f"channel {c} bus" for c in range(cfg.n_channels)} <= names
        assert "policy (page-granular)" in names

    def test_event_slices_match_ring(self, mixed_run, tmp_path):
        cfg, s = mixed_run
        doc = trace_export.chrome_trace(s, cfg)
        records, total, _ = obs.decode_events(s, cfg)
        x = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        reloc = [e for e in x if e["cat"] == "relocation"]
        xfer = [e for e in x if e["cat"] == "transfer"]
        assert len(reloc) == len(records)
        # each block-granular relocation with pages moved gets a companion
        # transfer slice on its die's channel-bus track
        assert len(xfer) == sum(
            1 for r in records if r["block"] >= 0 and r["pages"] > 0
        )
        for e in xfer:
            assert cfg.n_dies <= e["tid"] < cfg.n_dies + cfg.n_channels
        assert doc["otherData"]["events_total"] == total


class TestLevelsAndSummarize:
    def test_off_leaves_are_empty(self):
        cfg = geometry.tiny_config()
        s = st.init_state(cfg)
        assert s.obs_lat_mode.shape[0] == 0
        assert s.obs_lat_comp.shape[0] == 0
        assert s.obs_events.shape[0] == 0
        assert s.obs_ts.shape[0] == 0

    def test_off_summarize_has_no_obs_keys(self):
        cfg = geometry.tiny_config(policy=geometry.RARO, initial_pe=500)
        tr = workload.mixed_trace(cfg, 2 * cfg.chunk, theta=1.0, seed=0)
        s, _ = engine.run(cfg, tr)
        m = engine.summarize(s, cfg)
        assert not any(
            k.startswith(("lat_mode", "lat_attrib", "obs_", "tail_",
                          "conversion_events"))
            for k in m
        )

    def test_counters_level_histograms_only(self):
        cfg = geometry.tiny_config(policy=geometry.RARO, initial_pe=500,
                                   obs_level="counters")
        tr = workload.mixed_trace(cfg, 4 * cfg.chunk, theta=1.0, seed=0)
        s, _ = engine.run(cfg, tr)
        assert np.array_equal(np.asarray(s.obs_lat_mode).sum(axis=0),
                              np.asarray(s.lat_hist))
        assert s.obs_lat_comp.shape[0] == 0 and s.obs_events.shape[0] == 0
        m = engine.summarize(s, cfg)
        assert "lat_mode_counts" in m and "lat_attrib_us" not in m

    def test_invalid_level_rejected(self):
        with pytest.raises(ValueError, match="obs_level"):
            st.init_state(geometry.tiny_config(obs_level="everything"))

    def test_summarize_event_matrix_matches(self, mixed_run):
        cfg, s = mixed_run
        m = engine.summarize(s, cfg)
        assert m["obs_events_dropped"] == 0.0
        assert np.array_equal(np.asarray(m["conversion_events"]),
                              np.asarray(m["conversions"]))

    def test_summarize_json_round_trip(self, mixed_run):
        """Satellite: the full summarize dict (ndarray-free) survives a JSON
        round trip unchanged."""
        cfg, s = mixed_run
        m = engine.summarize(s, cfg)
        back = json.loads(json.dumps(m))
        assert back == m  # floats/lists only -> exact round trip


class TestSweepIntegration:
    def test_vmap_sweep_ships_attribution(self):
        """The obs leaves ride the stacked run axis: every sweep result
        carries its own per-run attribution, and the per-run JSON artifact
        serializes the nested-list metrics."""
        from repro.experiments import sweep

        spec = sweep.SweepSpec(
            scenario="mixed", n_requests=4 * 128,
            policies=(geometry.RARO,), initial_pe=(166, 833), seeds=(0,),
            base=_full_cfg(),
        )
        results = sweep.run_sweep(spec)
        assert len(results) == 2
        for r in results:
            counts = np.asarray(r["lat_mode_counts"])
            assert counts.shape == (modes.N_MODES, 64)
            assert counts.sum() == r["reads"]
            assert np.asarray(r["conversion_events"]).shape == (3, 3)
            json.loads(json.dumps({k: v for k, v in r.items()}))

    def test_write_artifacts_json_safe(self, tmp_path):
        from repro.experiments import sweep

        spec = sweep.SweepSpec(
            scenario="mixed", n_requests=2 * 128,
            policies=(geometry.RARO,), initial_pe=(166,), seeds=(0,),
            base=_full_cfg(),
        )
        results = sweep.run_sweep(spec)
        paths = sweep.write_artifacts(results, tmp_path)
        doc = json.loads(paths[0].read_text())
        assert doc["metrics"]["conversion_events"] == results[0][
            "conversion_events"
        ]
        names = [r[0] for r in doc["rows"]]
        assert any(n.endswith("tail_retry_share_qlc") for n in names)
        assert any(n.endswith("obs_events_total") for n in names)
