"""Tests for the fused background relocation kernel (DESIGN.md §2A).

The production path — multi-victim GC, reclaim demotion and block
conversion — is one kernel (``ftl.relocate_group`` + ``ftl._erase_many``).
These tests prove:

- fused GC with ``gc_victims_per_pass=1`` is bit-identical to the retained
  scalar ``gc_pass_reference`` on all integer/mapping state (float busy-time
  accumulators may differ by XLA reassociation inside a fused ``lax.cond``
  branch — the same standard as ``engine.write_path_reference``);
- ``_erase_many`` is equivalent to K sequential ``_erase`` calls;
- with k > 1 the fused victim set equals k sequential greedy argmin picks,
  and every relocation pass keeps the full ``state.check_invariants`` suite
  clean, preserves the mapped-page set, and conserves capacity when modes
  are unchanged.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import modes
from repro.ssdsim import engine, ftl, geometry, state as st, workload

# deterministic seed sweep instead of hypothesis: the bit-identity proof is
# an acceptance criterion and must run in tier-1 even without hypothesis
SEEDS = [0, 1, 7, 11, 101, 1234, 9999, 2**15]


def assert_states_match(a: st.SSDState, b: st.SSDState, tag=""):
    """Bitwise on integer/mapping state; allclose on float accumulators."""
    for name, x, y in zip(a._fields, a, b):
        x, y = np.asarray(x), np.asarray(y)
        if x.dtype.kind == "f":
            np.testing.assert_allclose(
                x, y, rtol=1e-6, atol=1e-6, err_msg=f"{tag}: float field {name}"
            )
        else:
            bad = np.nonzero(np.atleast_1d(x != y))[0]
            assert (x == y).all(), (
                f"{tag}: field {name} differs at {bad[:8]}: "
                f"a={np.atleast_1d(x)[bad][:8]} b={np.atleast_1d(y)[bad][:8]}"
            )


def _kill_pages(s: st.SSDState, cfg, rng, n_victim_blocks):
    """Unmap a random number of pages in ``n_victim_blocks`` random FULL
    blocks, making them GC victims with distinct-ish valid counts."""
    spb = cfg.slots_per_block
    l2p = np.asarray(s.l2p).copy()
    p2l = np.asarray(s.p2l).copy()
    bv = np.asarray(s.block_valid).copy()
    full = np.nonzero(np.asarray(s.block_state) == st.FULL)[0]
    picks = rng.choice(full, size=min(n_victim_blocks, len(full)), replace=False)
    for b in picks:
        slots = np.nonzero(p2l[b * spb:(b + 1) * spb] >= 0)[0] + b * spb
        if len(slots) < 2:
            continue
        nk = int(rng.integers(1, len(slots)))
        ks = rng.choice(slots, size=nk, replace=False)
        l2p[p2l[ks]] = -1
        p2l[ks] = -1
        bv[b] -= nk
    return s._replace(
        l2p=jnp.asarray(l2p), p2l=jnp.asarray(p2l), block_valid=jnp.asarray(bv)
    )


def _pressure_state(cfg, seed, n_victim_blocks=6, demote=0):
    """``init_state`` + random page kills (and optionally a few blocks
    converted to SLC/TLC first, for mode diversity among victims)."""
    rng = np.random.default_rng(seed)
    s = st.init_state(cfg)
    for i in range(demote):
        tgt = modes.TLC if i % 2 else modes.SLC
        s = ftl.migrate_block(s, jnp.int32(2 + i), jnp.int32(tgt), cfg)
    return _kill_pages(s, cfg, rng, n_victim_blocks)


class TestFusedGCBitIdentity:
    """gc_victims_per_pass=1 must reproduce the scalar reference exactly."""

    @pytest.mark.parametrize("seed", SEEDS)
    def test_property_k1_matches_reference(self, seed):
        cfg = geometry.tiny_config(gc_free_threshold=50, gc_victims_per_pass=1)
        s = _pressure_state(cfg, seed, n_victim_blocks=5, demote=seed % 3)
        a, b = s, s
        for step in range(3):  # chained passes: each starts from fused state
            a = ftl.gc_step(a, cfg)
            b = ftl.gc_step_reference(b, cfg)
            assert_states_match(a, b, tag=f"pass {step}")
        st.check_invariants(a, cfg, "fused k=1")

    def test_k1_matches_reference_after_engine_run(self):
        """States reached by a real write-heavy engine run under free-pool
        pressure agree between the fused and reference GC passes."""
        cfg = geometry.tiny_config(
            n_logical=3200, gc_free_threshold=14, gc_victims_per_pass=1,
            policy=geometry.RARO, initial_pe=500,
        )
        tr = workload.mixed_trace(cfg, 6 * cfg.chunk, 1.2, read_frac=0.3, seed=3)
        s, _ = engine.run(cfg, tr)
        assert float(s.n_erases) > 0  # the run actually exercised GC
        a = ftl.gc_step(s, cfg)
        b = ftl.gc_step_reference(s, cfg)
        assert_states_match(a, b, tag="post-run")
        st.check_invariants(a, cfg, "post-run fused")

    def test_no_op_above_watermark_is_exact(self):
        cfg = geometry.tiny_config(gc_free_threshold=2, gc_victims_per_pass=1)
        s = _pressure_state(cfg, 7, n_victim_blocks=3)
        a = ftl.gc_step(s, cfg)
        for name, x, y in zip(s._fields, s, a):
            assert (np.asarray(x) == np.asarray(y)).all(), name


class TestEraseMany:
    @pytest.mark.parametrize("seed", SEEDS)
    def test_property_matches_sequential_erase(self, seed):
        """One vectorized ``_erase_many`` == K sequential ``_erase`` calls.

        Victims are sorted ascending so the sequential loop's
        last-erase-per-LUN hint equals the fused segment_max hint; ints are
        bitwise, float busy time allclose (summation order).
        """
        cfg = geometry.tiny_config()
        rng = np.random.default_rng(seed)
        s = st.init_state(cfg)
        full = np.nonzero(np.asarray(s.block_state) == st.FULL)[0]
        k = int(rng.integers(1, 6))
        victims = np.sort(rng.choice(full, size=min(k, len(full)), replace=False))
        grp = rng.random(len(victims)) < 0.8
        a = ftl._erase_many(
            s, jnp.asarray(victims, jnp.int32), jnp.asarray(grp), cfg
        )
        b = s
        for v, g in zip(victims, grp):
            if g:
                b = ftl._erase(b, jnp.int32(v), cfg)
        assert_states_match(a, b, tag=f"victims={victims[grp]}")
        assert int(a.free_count) == int(s.free_count) + int(grp.sum())

    def test_masked_out_lanes_untouched(self):
        cfg = geometry.tiny_config()
        s = st.init_state(cfg)
        a = ftl._erase_many(
            s, jnp.asarray([0, 1], jnp.int32), jnp.zeros((2,), bool), cfg
        )
        for name, x, y in zip(s._fields, s, a):
            assert (np.asarray(x) == np.asarray(y)).all(), name


class TestMultiVictimGC:
    def test_victim_set_equals_sequential_greedy(self):
        """The fused top-k victim set equals k sequential greedy min-valid
        picks (selection replayed against the evolving reference state)."""
        k = 4
        cfg = geometry.tiny_config(gc_free_threshold=100, gc_victims_per_pass=k)
        s = _pressure_state(cfg, 11, n_victim_blocks=8)
        victims, ok = ftl.select_gc_victims(s, cfg, k)
        fused_picks = list(np.asarray(victims)[np.asarray(ok)])
        assert len(fused_picks) == k

        ppb = geometry.pages_per_block_host(cfg)
        ref = s
        greedy = []
        for _ in range(k):
            bs = np.asarray(ref.block_state)
            bv = np.asarray(ref.block_valid)
            bm = np.asarray(ref.block_mode)
            score = np.where(
                (bs == st.FULL) & (bv < ppb[bm]), bv, np.iinfo(np.int32).max
            )
            pick = int(np.argmin(score))
            assert score[pick] < np.iinfo(np.int32).max
            greedy.append(pick)
            ref = ftl.gc_pass_reference(ref, cfg)
        assert fused_picks == greedy

    @pytest.mark.parametrize("seed,k", [(s, 2 + s % 3) for s in SEEDS])
    def test_property_invariants_after_fused_pass(self, seed, k):
        """Any fused multi-victim pass keeps the full invariant suite clean
        and never unmaps a logical page."""
        cfg = geometry.tiny_config(gc_free_threshold=100, gc_victims_per_pass=k)
        s = _pressure_state(cfg, seed, n_victim_blocks=2 * k, demote=seed % 4)
        mapped0 = np.asarray(s.l2p) >= 0
        free0 = int(s.free_count)
        s2 = ftl.gc_step(s, cfg)
        st.check_invariants(s2, cfg, f"k={k}")
        np.testing.assert_array_equal(np.asarray(s2.l2p) >= 0, mapped0)
        assert int(s2.free_count) >= free0  # GC never shrinks the pool

    def test_qlc_only_pass_conserves_capacity(self):
        """Same-mode (QLC) relocation conserves usable capacity exactly:
        victims return to the free pool at QLC density and destinations are
        opened at QLC density."""
        k = 3
        cfg = geometry.tiny_config(gc_free_threshold=100, gc_victims_per_pass=k)
        s = _pressure_state(cfg, 5, n_victim_blocks=6)
        cap0 = int(st.usable_capacity_pages(s, cfg))
        s2 = ftl.gc_step(s, cfg)
        assert float(s2.n_erases) == k
        assert int(st.usable_capacity_pages(s2, cfg)) == cap0

    def test_reclaim_through_shared_kernel_keeps_invariants(self):
        """The fused reclaim demotion (now the same relocate_group kernel)
        still demotes each victim exactly once with clean invariants."""
        cfg = geometry.tiny_config()
        s = st.init_state(cfg)
        s = ftl.migrate_block(s, jnp.int32(0), jnp.int32(modes.TLC), cfg)
        s = ftl.migrate_block(s, jnp.int32(1), jnp.int32(modes.TLC), cfg)
        tlc_full = (np.asarray(s.block_mode) == modes.TLC) & (
            np.asarray(s.block_state) == st.FULL
        )
        victims = jnp.asarray(np.nonzero(tlc_full)[0][:2], jnp.int32)
        K = victims.shape[0]
        s2 = ftl.reclaim_victims(
            s, victims, jnp.ones((K,), bool),
            jnp.full((K,), modes.QLC, jnp.int32), cfg,
        )
        st.check_invariants(s2, cfg, "reclaim")
        assert (np.asarray(s2.block_state)[np.asarray(victims)] == st.FREE).all()
        assert (np.asarray(s2.l2p) >= 0).all()
