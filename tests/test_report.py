"""Tests for benchmarks/report.py: the committed BENCH_*.json artifacts must
render into the markdown summary without blowing up, and the key content
(throughput trend, A/B records, hockey-stick, scaling rows) must appear."""

import json
from pathlib import Path

import pytest

from benchmarks.report import (
    engine_report,
    latency_report,
    main,
    obs_report,
    render,
    sweep_report,
)

BENCH_DIR = Path(__file__).parent.parent / "benchmarks"


class TestRenderCommittedArtifacts:
    def test_render_all(self):
        md = render(BENCH_DIR)
        assert "### Engine throughput" in md
        assert "### Latency vs offered load" in md
        assert "### Sharded sweep scaling" in md

    def test_engine_table_has_sections_and_keys(self):
        doc = json.loads((BENCH_DIR / "BENCH_engine.json").read_text())
        lines = engine_report(doc)
        md = "\n".join(lines)
        for sec in ("read_only", "mixed", "gc_pressure"):
            assert sec in md
        assert "tiny (CI gate baseline)" in md
        # committed A/B records render with speedup columns
        assert "dedup_fix" in md and "speedup" in md

    def test_latency_hockey_stick_rows(self):
        doc = json.loads((BENCH_DIR / "BENCH_latency.json").read_text())
        md = "\n".join(latency_report(doc))
        for pol in doc["curves"]:
            assert f"**{pol}**" in md
        n_scales = len(next(iter(doc["curves"].values()))["arrival_scale"])
        assert md.count("| ") >= n_scales  # one table row per scale

    def test_sweep_rows(self):
        doc = json.loads((BENCH_DIR / "BENCH_sweep.json").read_text())
        md = "\n".join(sweep_report(doc))
        assert "sweep/scaling" in md

    def test_obs_attribution_tables(self):
        doc = json.loads((BENCH_DIR / "BENCH_obs.json").read_text())
        md = "\n".join(obs_report(doc))
        assert "### Latency attribution" in md
        for mode in ("SLC", "TLC", "QLC"):
            assert f"| {mode} |" in md
        assert "### Conversion / relocation events" in md
        assert "from → to" in md

    def test_main_appends_summary(self, tmp_path, monkeypatch):
        monkeypatch.delenv("GITHUB_STEP_SUMMARY", raising=False)
        summary = tmp_path / "summary.md"
        assert main(["--dir", str(BENCH_DIR), "--summary", str(summary)]) == 0
        assert "### Engine throughput" in summary.read_text()

    def test_missing_dir_raises(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            render(tmp_path)
