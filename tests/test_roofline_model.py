"""Validate the analytic roofline FLOPs model against XLA's compiled
cost_analysis. XLA counts while-loop bodies ONCE, so the comparison uses
1-layer configs where total = entry + one body — the regime where both
numbers measure the same thing."""

import jax
import pytest

from benchmarks.roofline import model_flops
from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.models import base, registry
from repro.training import optim, train_step as ts

SMALL_TRAIN = ShapeConfig("t", 512, 8, "train")


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "deepseek-7b"])
def test_analytic_flops_matches_compiled_one_layer(arch):
    cfg = ARCHS[arch].with_(n_layers=1, remat=False)
    api = registry.get_api(cfg)
    specs = api.specs()
    params_abs = base.abstract(specs)
    o_abs = base.abstract(optim.opt_state_specs(specs))
    inputs = registry.input_specs(cfg, SMALL_TRAIN)

    step = ts.make_train_step(cfg, optim.AdamWConfig())
    compiled = jax.jit(step).lower(params_abs, o_abs, inputs).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # pre-0.5 jax returns one dict per device
        ca = ca[0]
    hlo_flops = float(ca.get("flops", 0.0))
    analytic = model_flops(cfg, SMALL_TRAIN)["total"]

    # same order of magnitude and within 35% — the analytic model is used
    # to scale per-layer cost by n_layers, which XLA's counter cannot do.
    assert hlo_flops > 0
    ratio = analytic / hlo_flops
    assert 0.65 < ratio < 1.5, (analytic, hlo_flops, ratio)


def test_flops_scale_linearly_in_layers_analytically():
    shape = SMALL_TRAIN
    f1 = model_flops(ARCHS["tinyllama-1.1b"].with_(n_layers=1), shape)
    f2 = model_flops(ARCHS["tinyllama-1.1b"].with_(n_layers=2), shape)
    assert abs((f2["layers_fwd"] / f1["layers_fwd"]) - 2.0) < 1e-6
    assert f1["head_fwd"] == f2["head_fwd"]
