"""Tests for the distributed-runtime substrates: data pipeline, checkpoint
manager (atomicity, rotation, resume), watchdog failover logic, gradient
compression with error feedback, optimizer, and a short end-to-end
training-loss check."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.checkpoint.manager import CheckpointManager, WatchdogState
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.parallel import compression as comp
from repro.training import optim


class TestData:
    def test_deterministic_and_resumable(self):
        d = SyntheticLM(DataConfig(vocab=100, seq_len=16, global_batch=8))
        b1 = d.batch_at(5)
        b2 = d.batch_at(5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_host_sharding_partitions_batch(self):
        d = SyntheticLM(DataConfig(vocab=100, seq_len=16, global_batch=8))
        s0 = d.batch_at(3, shard=0, n_shards=2)
        s1 = d.batch_at(3, shard=1, n_shards=2)
        assert s0["tokens"].shape == (4, 16)
        assert not np.array_equal(s0["tokens"], s1["tokens"])

    def test_learnable_structure(self):
        cfg = DataConfig(vocab=100, seq_len=64, global_batch=4, noise=0.0)
        b = SyntheticLM(cfg).batch_at(0)
        pred = (b["tokens"] * cfg.mult + cfg.add) % cfg.vocab
        np.testing.assert_array_equal(pred, b["labels"])


class TestCheckpoint:
    def _tree(self, k=0):
        return {"a": jnp.arange(6.0) + k, "b": {"c": jnp.ones((2, 3)) * k}}

    def test_roundtrip(self, tmp_path):
        t = self._tree(3)
        ckpt.save(tmp_path / "c1", t, step=7)
        out, manifest = ckpt.restore(tmp_path / "c1", jax.tree_util.tree_map(jnp.zeros_like, t))
        assert manifest["step"] == 7
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)), t, out
        )

    def test_async_save(self, tmp_path):
        t = self._tree(1)
        join = ckpt.save(tmp_path / "c2", t, step=1, async_=True)
        join()
        out, _ = ckpt.restore(tmp_path / "c2", t)
        assert float(out["a"][0]) == 1.0

    def test_manager_rotation_and_resume(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2, interval=10, async_=False)
        for s in (10, 20, 30):
            mgr.save(s, self._tree(s))
        assert mgr.all_steps() == [20, 30]
        step, tree, _ = mgr.restore_latest(self._tree(0))
        assert step == 30 and float(tree["a"][0]) == 30.0

    def test_manager_skips_corrupt(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=3, interval=1, async_=False)
        mgr.save(1, self._tree(1))
        bad = mgr.dir_for(2)
        bad.mkdir()
        (bad / "manifest.json").write_text("{not json")
        assert mgr.latest() == 1

    def test_elastic_restore_dtype_and_shape_checked(self, tmp_path):
        t = self._tree(2)
        ckpt.save(tmp_path / "c3", t, step=1)
        wrong = {"a": jnp.zeros((5,)), "b": {"c": jnp.zeros((2, 3))}}
        with pytest.raises(ValueError):
            ckpt.restore(tmp_path / "c3", wrong)


class TestWatchdog:
    def test_failover_plan(self):
        w = WatchdogState(n_hosts=4, timeout_s=10)
        now = 100.0
        for h in range(4):
            w.heartbeat(h, now)
        assert w.plan(now + 5, dp_width=4)["restart_required"] is False
        # host 3 goes silent
        for h in range(3):
            w.heartbeat(h, now + 30)
        plan = w.plan(now + 30, dp_width=4)
        assert plan["dead"] == [3]
        assert plan["restart_required"] and plan["new_dp_width"] == 2
        assert plan["action"] == "elastic_restart_from_latest_checkpoint"


class TestCompression:
    def test_error_feedback_preserves_sum(self):
        # With EF, the cumulative applied gradient tracks the exact one.
        rng = np.random.default_rng(0)
        g_true = [jnp.asarray(rng.normal(size=(64,)), jnp.float32) for _ in range(50)]
        err = None
        applied = jnp.zeros((64,))
        for g in g_true:
            q, s, err = comp.compress(g, err)
            applied = applied + comp.decompress(q, s)
        exact = sum(g_true)
        rel = float(jnp.linalg.norm(applied - exact) / jnp.linalg.norm(exact))
        assert rel < 0.02, rel  # residual bounded by one quantization step

    def test_without_ef_is_worse(self):
        rng = np.random.default_rng(0)
        g_true = [jnp.asarray(rng.normal(size=(64,)) * (0.01 if i % 2 else 1.0), jnp.float32)
                  for i in range(50)]
        err = None
        with_ef = jnp.zeros((64,))
        no_ef = jnp.zeros((64,))
        for g in g_true:
            q, s, err = comp.compress(g, err)
            with_ef += comp.decompress(q, s)
            q2, s2, _ = comp.compress(g, None)
            no_ef += comp.decompress(q2, s2)
        exact = sum(g_true)
        e_ef = float(jnp.linalg.norm(with_ef - exact))
        e_no = float(jnp.linalg.norm(no_ef - exact))
        assert e_ef < e_no

    def test_tree_api(self):
        g = {"w": jnp.ones((4, 4)), "b": jnp.full((4,), 0.5)}
        q, s, e = comp.compress_tree(g, None)
        out = comp.decompress_tree(q, s)
        np.testing.assert_allclose(np.asarray(out["w"]), 1.0, atol=1e-2)


class TestOptim:
    def test_adamw_descends_quadratic(self):
        p = {"x": jnp.array([5.0, -3.0])}
        st = optim.init(p)
        cfg = optim.AdamWConfig(lr=0.1, warmup=1, total_steps=200, weight_decay=0.0)
        for _ in range(150):
            g = {"x": 2 * p["x"]}
            p, st, _ = optim.update(cfg, p, g, st)
        assert float(jnp.abs(p["x"]).max()) < 0.2

    def test_clip_norm(self):
        p = {"x": jnp.zeros(3)}
        st = optim.init(p)
        cfg = optim.AdamWConfig(lr=1e-3, clip_norm=1.0)
        _, _, m = optim.update(cfg, p, {"x": jnp.full((3,), 100.0)}, st)
        assert float(m["grad_norm"]) > 1.0  # reported pre-clip


@pytest.mark.slow
def test_end_to_end_training_loss_decreases(tmp_path):
    from repro.launch.train import run

    _, hist = run("tinyllama-1.1b", smoke=True, steps=60, batch=8, seq=64,
                  ckpt_dir=str(tmp_path / "ck"), ckpt_interval=25, lr=2e-3,
                  log_every=10)
    first, last = hist[0][1], hist[-1][1]
    assert last < first - 0.5, (first, last)
    # resume works
    _, hist2 = run("tinyllama-1.1b", smoke=True, steps=70, batch=8, seq=64,
                   ckpt_dir=str(tmp_path / "ck"), ckpt_interval=25, lr=2e-3,
                   log_every=10)
    assert hist2[0][0] >= 60  # picked up from the checkpoint
