"""Sharded-vs-vmapped sweep equivalence (DESIGN.md §7.3).

The sharded executor must reproduce the single-device vmapped results
*exactly* — same ``engine.summarize`` dicts, bit for bit — for every grid
shape: even splits, uneven grids that force padding, and grids smaller than
the device count. The multi-device cases need more than one visible device,
so the tier-1 run (1 CPU device) skips them; CI exercises them in a
dedicated step under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

import jax
import pytest

from repro.experiments import sweep
from repro.ssdsim import geometry

TINY = geometry.tiny_config()
N_DEV = len(jax.devices())

_needs_devices = pytest.mark.skipif(
    N_DEV < 2,
    reason="needs >=2 devices; run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8",
)


def multi_device(fn):
    """Skips on one device, and carries the ``multi_device`` marker so CI's
    dedicated faked-device step selects exactly the tests the tier-1 run
    skipped (``-m multi_device``) instead of re-running the whole file."""
    return pytest.mark.multi_device(_needs_devices(fn))


def _spec(**kw):
    d = dict(
        scenario="read_disturb_hammer",
        n_requests=2_048,
        policies=(geometry.BASELINE, geometry.RARO),
        initial_pe=(166, 833),
        seeds=(0,),
        base=TINY,
    )
    d.update(kw)
    return sweep.SweepSpec(**d)


# same runs, same order, every summarize value exactly equal — the shared
# checker the scaling benchmark also runs after its timing passes
_assert_identical = sweep.assert_results_identical


class TestShardedEquivalence:
    def test_one_device_mesh_matches_vmap(self):
        """devices=1 runs the full shard_map machinery on a 1-device mesh;
        must be indistinguishable from the plain vmap path (runs in the
        tier-1 suite, no faked devices needed)."""
        spec = _spec()
        _assert_identical(sweep.run_sweep(spec), sweep.run_sweep(spec, devices=1))

    @multi_device
    def test_even_grid(self):
        """Grid divides the device count: no padding."""
        spec = _spec(seeds=(0, 1))  # 4 runs per policy group
        _assert_identical(sweep.run_sweep(spec), sweep.run_sweep(spec, devices=2))

    @multi_device
    def test_uneven_grid_forces_padding(self):
        """3 runs per group on 2 devices: one dummy pad, dropped on host."""
        spec = _spec(initial_pe=(166,), seeds=(0, 1, 2))
        _assert_identical(sweep.run_sweep(spec), sweep.run_sweep(spec, devices=2))

    @multi_device
    def test_grid_smaller_than_device_count(self):
        """1 run per group on every visible device: all but one lane is pad."""
        spec = _spec(initial_pe=(500,), seeds=(0,))
        _assert_identical(
            sweep.run_sweep(spec), sweep.run_sweep(spec, devices="all")
        )

    @multi_device
    def test_open_loop_arrival_scale_axis(self):
        """The open-loop engine (arrival_ms + RunKnobs.arrival_scale) shards
        identically: queueing telemetry is per-run state, no cross-lane."""
        spec = _spec(
            scenario="hammer_openloop",
            policies=(geometry.RARO,),
            initial_pe=(500,),
            arrival_scale=(0.5, 1.0, 4.0),
            scenario_kw=(("rate_iops", 20_000.0),),
        )
        res = sweep.run_sweep(spec)
        _assert_identical(res, sweep.run_sweep(spec, devices=2))
        assert any(r["read_queue_delay_us"] > 0 for r in res)

    def test_too_many_devices_clamps_with_warning(self):
        # over-asking devices clamps to the visible count (with a warning)
        # instead of aborting the sweep — results are unchanged
        with pytest.warns(UserWarning, match="clamping"):
            res = sweep.run_sweep(_spec(), devices=N_DEV + 1)
        _assert_identical(res, sweep.run_sweep(_spec(), devices=N_DEV))

    def test_zero_devices_raises(self):
        with pytest.raises(ValueError, match="devices"):
            sweep.run_sweep(_spec(), devices=0)
