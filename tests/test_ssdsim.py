"""Integration + property tests for the flash-simulator layer."""

import jax.numpy as jnp
import numpy as np
import pytest
from hyp_fallback import given, settings
from hyp_fallback import st as st_h

from repro.core import modes
from repro.ssdsim import engine, ftl, geometry, state as st, workload

TINY = geometry.tiny_config()


def _invariants(s, cfg):
    """Full-state consistency — delegated to the shared
    ``state.check_invariants`` helper (mapping bijection, valid counts,
    free-pool bookkeeping, cursor sanity)."""
    st.check_invariants(s, cfg)


class TestInit:
    def test_initial_capacity_is_full_qlc(self):
        s = st.init_state(TINY)
        cap = int(st.usable_capacity_pages(s, TINY))
        assert cap == TINY.n_blocks * TINY.slots_per_block

    def test_initial_mapping(self):
        s = st.init_state(TINY)
        _invariants(s, TINY)
        assert (np.array(s.l2p) >= 0).all()


class TestEngine:
    @pytest.fixture(scope="class")
    def raro_run(self):
        cfg = geometry.tiny_config(policy=geometry.RARO, initial_pe=500)
        tr = workload.zipf_read_trace(cfg, 20_000, 1.2, seed=1)
        s, ys = engine.run(cfg, tr)
        return cfg, s, ys

    def test_invariants_after_run(self, raro_run):
        cfg, s, _ = raro_run
        _invariants(s, cfg)

    def test_no_data_loss(self, raro_run):
        cfg, s, _ = raro_run
        assert (np.array(s.l2p) >= 0).all()  # every logical page still mapped

    def test_conversions_happened(self, raro_run):
        cfg, s, _ = raro_run
        conv = np.array(s.n_conversions)
        assert conv[modes.QLC, modes.SLC] + conv[modes.QLC, modes.TLC] > 0

    def test_capacity_loss_matches_mode_deficit(self, raro_run):
        cfg, s, _ = raro_run
        ppb = np.array(geometry.pages_per_block(cfg))
        bm, bs = np.array(s.block_mode), np.array(s.block_state)
        nonfree = bs != st.FREE
        deficit = (ppb[modes.QLC] - ppb[bm[nonfree]]).sum()
        cap = int(st.usable_capacity_pages(s, cfg))
        assert cap == cfg.n_blocks * cfg.slots_per_block - deficit

    def test_baseline_never_converts(self):
        cfg = geometry.tiny_config(policy=geometry.BASELINE, initial_pe=500)
        tr = workload.zipf_read_trace(cfg, 5_000, 1.2, seed=1)
        s, _ = engine.run(cfg, tr)
        assert float(s.n_conversions.sum()) == 0.0
        assert float(s.n_migrated_pages) == 0.0

    def test_raro_beats_baseline_iops(self):
        res = {}
        for pol in (geometry.BASELINE, geometry.RARO):
            cfg = geometry.tiny_config(policy=pol, initial_pe=833)
            tr = workload.zipf_read_trace(cfg, 20_000, 1.2, seed=1)
            s, _ = engine.run(cfg, tr)
            res[pol] = engine.summarize(s, cfg)["iops"]
        assert res[geometry.RARO] > 3.0 * res[geometry.BASELINE]

    def test_raro_saves_capacity_vs_hotness(self):
        res = {}
        for pol in (geometry.HOTNESS, geometry.RARO):
            cfg = geometry.tiny_config(policy=pol, initial_pe=166)
            tr = workload.zipf_read_trace(cfg, 20_000, 1.2, seed=1)
            s, _ = engine.run(cfg, tr)
            res[pol] = engine.summarize(s, cfg)
        assert (
            res[geometry.RARO]["capacity_loss_gib"]
            <= res[geometry.HOTNESS]["capacity_loss_gib"]
        )
        assert (
            res[geometry.RARO]["migrated_pages"]
            < res[geometry.HOTNESS]["migrated_pages"]
        )

    def test_retry_counts_grow_with_wear(self):
        out = {}
        for pe in (166, 833):
            cfg = geometry.tiny_config(policy=geometry.BASELINE, initial_pe=pe)
            tr = workload.zipf_read_trace(cfg, 5_000, 1.2, seed=1)
            s, _ = engine.run(cfg, tr)
            out[pe] = engine.summarize(s, cfg)["retries_per_read"]
        assert out[833] > out[166]

    def test_write_path(self):
        cfg = geometry.tiny_config(policy=geometry.RARO, initial_pe=166)
        tr = workload.mixed_trace(cfg, 3_000, 1.2, read_frac=0.6, seed=2)
        s, _ = engine.run(cfg, tr)
        _invariants(s, cfg)
        assert float(s.n_writes) > 0
        assert (np.array(s.l2p) >= 0).all()

    def test_write_latency_histogram(self):
        cfg = geometry.tiny_config(policy=geometry.RARO, initial_pe=166)
        tr = workload.mixed_trace(cfg, 3_000, 1.2, read_frac=0.6, seed=2)
        s, ys = engine.run(cfg, tr)
        # every successful write lands in exactly one histogram bin, and the
        # per-chunk histograms stack to the cumulative one
        assert float(s.w_lat_hist.sum()) == float(s.n_writes)
        np.testing.assert_allclose(
            np.asarray(ys.w_lat_hist).sum(0), np.asarray(s.w_lat_hist), rtol=1e-6
        )
        m = engine.summarize(s, cfg)
        assert m["write_lat_p50_us"] > 0
        assert m["write_lat_p99_us"] >= m["write_lat_p50_us"]

    def test_read_only_run_records_no_writes(self):
        cfg = geometry.tiny_config(policy=geometry.RARO, initial_pe=166)
        tr = workload.zipf_read_trace(cfg, 2_000, 1.2, seed=3)
        s, _ = engine.run(cfg, tr)
        assert float(s.w_lat_hist.sum()) == 0.0
        assert engine.summarize(s, cfg)["write_lat_p50_us"] == 0.0

    def test_single_thread_summary(self, raro_run):
        cfg, s, _ = raro_run
        m1 = engine.summarize(s, cfg, threads=1)
        m4 = engine.summarize(s, cfg, threads=4)
        assert m1["iops"] > 0 and m4["iops"] > 0


class TestFTL:
    def test_migrate_block_roundtrip(self):
        cfg = TINY
        s = st.init_state(cfg)
        cap0 = int(st.usable_capacity_pages(s, cfg))
        s2 = ftl.migrate_block(s, jnp.int32(0), jnp.int32(modes.SLC), cfg)
        _invariants(s2, cfg)
        # all pages from block 0 still mapped somewhere else
        assert (np.array(s2.l2p)[: cfg.slots_per_block] >= 0).all()
        assert (np.array(s2.l2p)[: cfg.slots_per_block] >= cfg.slots_per_block).all()
        # capacity shrank by the SLC deficit of the opened blocks
        cap1 = int(st.usable_capacity_pages(s2, cfg))
        assert cap1 < cap0
        assert float(s2.n_erases) == 1.0

    def test_migrate_pages_moves_and_invalidates(self):
        cfg = TINY
        s = st.init_state(cfg)
        lpns = jnp.array([0, 1, 2, -1, -1, -1, 7, 9] + [-1] * 8, jnp.int32)
        s2 = ftl.migrate_pages(s, lpns, jnp.int32(modes.SLC), cfg)
        _invariants(s2, cfg)
        moved = np.array(s2.l2p)[[0, 1, 2, 7, 9]]
        assert (moved != np.array([0, 1, 2, 7, 9])).all()
        bm = np.array(s2.block_mode)
        assert (bm[moved // cfg.slots_per_block] == modes.SLC).all()

    def test_gc_reclaims_space(self):
        cfg = geometry.tiny_config(gc_free_threshold=100)  # force GC pressure
        s = st.init_state(cfg)
        # make blocks 0 and 1 mostly-invalid GC victims (16/64 valid each)
        spb = cfg.slots_per_block
        kill = jnp.concatenate(
            [jnp.arange(0, spb - 16), jnp.arange(spb, 2 * spb - 16)]
        ).astype(jnp.int32)
        s = s._replace(
            p2l=s.p2l.at[kill].set(-1),
            l2p=s.l2p.at[kill].set(-1),
            block_valid=s.block_valid.at[jnp.array([0, 1])].add(-(spb - 16)),
        )
        free0 = int(ftl.free_block_count(s))
        # two passes: both victims compact into ONE shared open block, so the
        # pool nets at least one extra free block.
        s2 = ftl.gc_step(ftl.gc_step(s, cfg), cfg)
        _invariants(s2, cfg)
        assert int(ftl.free_block_count(s2)) >= free0 + 1
        assert float(s2.n_erases) == 2.0

    def test_gc_never_fires_above_free_threshold(self):
        """Regression (ISSUE 2): with a healthy free pool GC must be an
        explicit no-op even when mostly-invalid victim blocks exist."""
        cfg = geometry.tiny_config(gc_free_threshold=2)  # pool starts at 40
        s = st.init_state(cfg)
        spb = cfg.slots_per_block
        kill = jnp.arange(0, spb - 16).astype(jnp.int32)  # block 0 mostly invalid
        s = s._replace(
            p2l=s.p2l.at[kill].set(-1),
            l2p=s.l2p.at[kill].set(-1),
            block_valid=s.block_valid.at[0].add(-(spb - 16)),
        )
        assert int(ftl.free_block_count(s)) >= cfg.gc_free_threshold
        s2 = ftl.gc_step(s, cfg)
        assert float(s2.n_erases) == 0.0
        for name, a, b in zip(s._fields, s, s2):
            assert (np.asarray(a) == np.asarray(b)).all(), name

    def test_fused_reclaim_matches_block_migration_counters(self):
        """The fused demotion pass migrates + erases each victim exactly once
        and keeps the state invariants."""
        cfg = geometry.tiny_config()
        s = st.init_state(cfg)
        # convert blocks 0 and 1 to TLC-full demotion candidates
        s = ftl.migrate_block(s, jnp.int32(0), jnp.int32(modes.TLC), cfg)
        s = ftl.migrate_block(s, jnp.int32(1), jnp.int32(modes.TLC), cfg)
        tlc_full = (np.array(s.block_mode) == modes.TLC) & (
            np.array(s.block_state) == st.FULL
        )
        assert tlc_full.any()
        victims = jnp.asarray(np.nonzero(tlc_full)[0][:2], jnp.int32)
        K = victims.shape[0]
        conv0 = float(s.n_conversions[modes.TLC, modes.QLC])
        erases0 = float(s.n_erases)
        s2 = ftl.reclaim_victims(
            s,
            victims,
            jnp.ones((K,), bool),
            jnp.full((K,), modes.QLC, jnp.int32),
            cfg,
        )
        _invariants(s2, cfg)
        assert float(s2.n_conversions[modes.TLC, modes.QLC]) == conv0 + K
        assert float(s2.n_erases) == erases0 + K
        assert (np.array(s2.block_state)[np.array(victims)] == st.FREE).all()


@settings(max_examples=10, deadline=None)
@given(
    seed=st_h.integers(0, 2**16),
    theta=st_h.floats(0.6, 1.5),
    pol=st_h.sampled_from([geometry.BASELINE, geometry.HOTNESS, geometry.RARO]),
    pe=st_h.integers(0, 1000),
)
def test_property_engine_invariants(seed, theta, pol, pe):
    """Any (workload, policy, wear) keeps the FTL state consistent."""
    cfg = geometry.tiny_config(policy=pol, initial_pe=pe)
    tr = workload.zipf_read_trace(cfg, 2_000, theta, seed=seed)
    s, ys = engine.run(cfg, tr)
    _invariants(s, cfg)
    cap = np.array(ys.capacity_pages)
    assert (cap > 0).all()
    assert (np.array(ys.free_blocks) >= 0).all()
