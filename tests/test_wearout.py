"""Wear-correlated fault model, die-parity rebuild and spare-pool tests
(DESIGN.md §2D).

Four things are pinned here:

  1. The wear curve itself — ``rate * (1 + slope * (pe/rated)^power)`` is
     monotone in P/E, matches the analytic curve empirically, and with
     ``slope == 0`` is *exactly* the flat PR-7 rate (multiplier bit-equal
     to 1.0, so the draw comparison is unchanged).
  2. Traced-vs-static neutrality: a run whose new knob fields (read-fail
     rate, wear slope, parity, spare pool) are explicit neutral arrays is
     bit-identical to one where they are ``None`` and fall back to the
     static config — the property that lets one compiled grid mix old-style
     and wear-aware runs.
  3. Die-parity rebuild: uncorrectable reads trigger stripe reconstruction
     (counted, latency-attributed to its own component, histogram mass
     conserved) and a second peer fault during the rebuild is data loss.
  4. Spare-pool degradation: retirements drain the pool, exhaustion flips
     the device read-only (writes dropped and counted) and the mapping
     stays coherent throughout — including under random fault schedules.
"""

import jax
import numpy as np
import pytest
from hyp_fallback import given, settings
from hyp_fallback import st as st_h

from repro.core import faults
from repro.experiments import sweep
from repro.ssdsim import engine, ftl, geometry, obs, policies, state as st, workload

TINY = geometry.tiny_config()


def _mixed(cfg, n=4_096, seed=1, read_frac=0.7, write_theta=None):
    return workload.mixed_trace(cfg, n, 1.2, read_frac=read_frac, seed=seed,
                                write_theta=write_theta)


def _params(**kw):
    d = dict(max_read_retries=np.int32(-1),
             prog_fail_rate=np.float32(0.0), erase_fail_rate=np.float32(0.0),
             read_fail_rate=np.float32(0.0), wear_slope=np.float32(0.0),
             parity_rebuild=np.int32(0), seed=np.int32(1),
             read_recovery_us=5_000.0, wear_power=4.0)
    d.update(kw)
    return faults.FaultParams(**d)


# ------------------------------- wear curve --------------------------------


class TestWearCurve:
    def test_zero_slope_multiplier_is_exactly_one(self):
        p = _params(wear_slope=np.float32(0.0))
        pe = np.arange(0, 3_000, 7, dtype=np.int32)
        m = np.asarray(faults.wear_mult(p, pe, 1_000.0))
        # bit-exact 1.0: `rate * wear_mult` must equal the flat PR-7 rate
        assert (m == np.float32(1.0)).all()

    def test_zero_slope_draws_ignore_rated_limit(self):
        # with the curve off, neither pe/rated scaling nor the rated limit
        # may leak into the draw comparison (pe still seeds the counter
        # hash, as it always has)
        ids = np.arange(32_768, dtype=np.int32)
        pe = (ids * 13 % 900).astype(np.int32)
        p = _params(read_fail_rate=np.float32(0.05))
        a = np.asarray(faults.read_fails(p, ids, pe, 1_000.0))
        b = np.asarray(faults.read_fails(p, ids, pe, 3_000.0))
        np.testing.assert_array_equal(a, b)

    def test_multiplier_monotone_in_pe(self):
        p = _params(wear_slope=np.float32(8.0))
        pe = np.linspace(0, 1_000, 21).astype(np.int32)
        m = np.asarray(faults.wear_mult(p, pe, 1_000.0), np.float64)
        assert (np.diff(m) >= 0).all() and m[-1] > m[0]
        assert m[0] == 1.0 and m[-1] == pytest.approx(9.0)

    def test_fire_rate_monotone_and_matches_curve(self):
        """Empirical firing fraction tracks rate * (1 + slope*(pe/rated)^4)
        across drive life, for the per-page and per-block draw classes."""
        n = 100_000
        ids = np.arange(n, dtype=np.int32)
        p = _params(prog_fail_rate=np.float32(0.02),
                    read_fail_rate=np.float32(0.02),
                    wear_slope=np.float32(8.0))
        for draw in (faults.prog_fails, faults.read_fails):
            frac = []
            for pe in (0, 250, 500, 750, 950):
                fires = np.asarray(draw(p, ids, np.full(n, pe, np.int32),
                                        1_000.0))
                frac.append(fires.mean())
                want = 0.02 * (1.0 + 8.0 * (pe / 1_000.0) ** 4)
                assert frac[-1] == pytest.approx(want, rel=0.15, abs=0.002)
            assert (np.diff(frac) > 0).all()

    def test_saturated_rate_always_fires(self):
        ids = np.arange(4_096, dtype=np.int32)
        p = _params(erase_fail_rate=np.float32(0.2),
                    wear_slope=np.float32(50.0))
        fires = np.asarray(faults.erase_fails(
            p, ids, np.full(4_096, 990, np.int32), 1_000.0))
        assert fires.all()  # 0.2 * (1 + 50*0.96) >> 1

    def test_knob_fields_fall_back_to_config(self):
        cfg = geometry.tiny_config(read_fail_rate=0.125, fault_wear_slope=3.0,
                                   parity_rebuild=True, spare_blocks=9)
        # knob-armed run (prog_fail_rate set selects the knob path) whose
        # new fields are unset: they must resolve from the static config
        k = policies.RunKnobs(r1=1, r2_override=-1, initial_pe=500,
                              prog_fail_rate=np.float32(0.0),
                              erase_fail_rate=np.float32(0.0),
                              max_read_retries=np.int32(-1),
                              fault_seed=np.int32(1))
        p = faults.params_for(cfg, k)
        assert float(p.read_fail_rate) == pytest.approx(0.125)
        assert float(p.wear_slope) == pytest.approx(3.0)
        assert int(p.parity_rebuild) == 1
        # and explicit knob values win over the statics
        k2 = k._replace(read_fail_rate=np.float32(0.5),
                        fault_wear_slope=np.float32(7.0),
                        parity_rebuild=np.int32(0))
        p2 = faults.params_for(cfg, k2)
        assert float(p2.read_fail_rate) == pytest.approx(0.5)
        assert float(p2.wear_slope) == pytest.approx(7.0)
        assert int(p2.parity_rebuild) == 0

    def test_engine_uncorrectables_rise_with_drive_age(self):
        """Acceptance criterion: same trace, same rates — an old device
        (P/E 833 of 1000) must see more uncorrectable reads than a young
        one (P/E 166) once the wear curve is armed."""
        mk = lambda pe: geometry.tiny_config(  # noqa: E731
            policy=geometry.BASELINE, initial_pe=pe,
            read_fail_rate=0.01, fault_wear_slope=8.0, fault_seed=1)
        # near-uniform reads: the draw is deterministic per (slot, pe), so a
        # skewed trace would re-sample a handful of slots' luck instead of
        # the population rate
        tr = workload.zipf_read_trace(mk(100), 8_192, 0.3, seed=1)
        s_young, _ = engine.run(mk(100), tr)
        s_old, _ = engine.run(mk(950), tr)
        assert float(s_young.n_uncorrectable) > 0
        assert float(s_old.n_uncorrectable) > 2.0 * float(s_young.n_uncorrectable)


# --------------------- traced-neutral-knob bit identity --------------------


class TestNeutralKnobBitIdentity:
    def test_neutral_arrays_match_config_fallback(self):
        """New knob fields passed as explicit neutral arrays (rate 0, slope
        0, parity off, unbounded spares) must reproduce the program where
        they are ``None`` and resolve from the static config — bit for bit
        across every state leaf."""
        R = 2
        cfg = geometry.tiny_config(policy=geometry.RARO)
        tr = _mixed(cfg, n=2_048, read_frac=0.5, write_theta=2.0)
        lpns = np.broadcast_to(np.asarray(tr["lpn"], np.int32),
                               (R, *tr["lpn"].shape))
        ops = np.broadcast_to(np.asarray(tr["op"], np.int32),
                              (R, *tr["op"].shape))
        base = dict(
            r1=np.full(R, cfg.r1, np.int32),
            r2_override=np.full(R, -1, np.int32),
            initial_pe=np.full(R, 833, np.int32),
            prog_fail_rate=np.full(R, 0.05, np.float32),
            erase_fail_rate=np.full(R, 0.05, np.float32),
            max_read_retries=np.full(R, 6, np.int32),
            fault_seed=np.arange(1, R + 1, dtype=np.int32),
        )
        k_none = policies.RunKnobs(**base)
        k_neutral = policies.RunKnobs(
            **base,
            read_fail_rate=np.zeros(R, np.float32),
            fault_wear_slope=np.zeros(R, np.float32),
            parity_rebuild=np.zeros(R, np.int32),
            spare_blocks=np.full(R, -1, np.int32),
        )
        sa = jax.device_get(sweep._sweep_jit(cfg, lpns, ops, True, k_none, None))
        sb = jax.device_get(sweep._sweep_jit(cfg, lpns, ops, True, k_neutral, None))
        for name, a, b in zip(sa._fields, sa, sb):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"state leaf {name!r} diverged under traced "
                        f"neutral wear/parity/spare knobs")


# ----------------------------- parity rebuild ------------------------------


class TestParityRebuild:
    @pytest.fixture(scope="class")
    def runs(self):
        mk = lambda **kw: geometry.tiny_config(  # noqa: E731
            policy=geometry.BASELINE, initial_pe=900, obs_level="full",
            max_read_retries=2, read_fail_rate=0.01, fault_seed=1, **kw)
        cfg = mk(parity_rebuild=True)
        tr = workload.zipf_read_trace(cfg, 8_192, 1.2, seed=1)
        s, _ = engine.run(cfg, tr)
        # parity off *and* free ECC recovery: identical draws, identical
        # retries — the only delta left is the rebuild work itself
        cfg0 = mk(read_recovery_us=0.0)
        s0, _ = engine.run(cfg0, tr)
        return cfg, jax.device_get(s), cfg0, jax.device_get(s0)

    def test_rebuilds_fire_and_are_bounded(self, runs):
        cfg, s, _, s0 = runs
        assert float(s.n_uncorrectable) > 0
        assert float(s.n_rebuilds) == float(s.n_uncorrectable)
        assert 0.0 <= float(s.n_data_loss) <= float(s.n_rebuilds)
        # parity off: same uncorrectables, no rebuilds, no loss
        assert float(s0.n_uncorrectable) == float(s.n_uncorrectable)
        assert float(s0.n_rebuilds) == 0.0
        assert float(s0.n_data_loss) == 0.0

    def test_rebuild_latency_attributed_and_mass_conserved(self, runs):
        cfg, s, _, s0 = runs
        comp = np.asarray(s.obs_lat_comp, np.float64)
        assert comp[:, obs.COMP_REBUILD].sum() > 0.0
        assert np.asarray(s0.obs_lat_comp)[:, obs.COMP_REBUILD].sum() == 0.0
        # attribution never loses a read: per-mode counts still cover the
        # total histogram bit-exactly with the rebuild lane split out
        assert np.array_equal(np.asarray(s.obs_lat_mode).sum(axis=0),
                              np.asarray(s.lat_hist))

    def test_rebuild_charges_the_lattice(self, runs):
        """Rebuild reads n_dies-1 stripe peers and ships their pages over
        the channels: against the free-recovery baseline (same draws, same
        retries) the reconstruction must show up as extra die busy time,
        extra channel busy time, and longer read service."""
        cfg, s, cfg0, s0 = runs
        assert float(np.asarray(s.die_busy_ms).sum()) > \
            float(np.asarray(s0.die_busy_ms).sum())
        assert float(np.asarray(s.chan_busy_ms).sum()) > \
            float(np.asarray(s0.chan_busy_ms).sum())
        assert float(s.svc_sum_ms) > float(s0.svc_sum_ms)

    def test_summary_exposes_rebuild_counters(self, runs):
        cfg, s, _, _ = runs
        m = engine.summarize(s, cfg)
        assert m["rebuilds"] == float(s.n_rebuilds) > 0
        assert m["data_loss"] == float(s.n_data_loss)

    def test_single_die_device_never_rebuilds(self):
        cfg = geometry.tiny_config(
            policy=geometry.BASELINE, initial_pe=900, n_channels=1,
            luns_per_channel=1, n_logical=768,  # 16 blocks on the one die
            max_read_retries=2, read_fail_rate=0.01,
            parity_rebuild=True, fault_seed=1)
        tr = workload.zipf_read_trace(cfg, 4_096, 1.2, seed=1)
        s, _ = engine.run(cfg, tr)
        # no stripe peers -> reconstruction impossible: flat ECC penalty
        # only, and no data-loss accounting either
        assert float(s.n_uncorrectable) > 0
        assert float(s.n_rebuilds) == 0.0
        assert float(s.n_data_loss) == 0.0


# ------------------------------- spare pool --------------------------------


def _pressure_cfg(**kw):
    # the gc_pressure shape from tests/test_faults.py: tiny free pool +
    # write-heavy Zipf overwrites so GC erases fire on nearly every chunk
    base = dict(policy=geometry.BASELINE, initial_pe=500, n_logical=2_944,
                gc_free_threshold=18, gc_victims_per_pass=4,
                erase_fail_rate=0.1, fault_seed=1)
    base.update(kw)
    return geometry.tiny_config(**base)


class TestSparePool:
    @pytest.fixture(scope="class")
    def drained(self):
        cfg = _pressure_cfg(spare_blocks=2)
        tr = _mixed(cfg, n=16_384, read_frac=0.1, write_theta=2.0)
        s, _ = engine.run(cfg, tr)
        return cfg, jax.device_get(s)

    def test_retirements_consume_spares_until_dry(self, drained):
        cfg, s = drained
        assert float(s.n_erase_fails) > 2  # enough failures to drain 2 spares
        assert int(s.spare_total) == 2
        assert int(s.spare_count) == 0
        st.check_invariants(s, cfg)

    def test_exhaustion_flips_read_only_without_corruption(self, drained):
        cfg, s = drained
        # writes after exhaustion are dropped-and-counted, never mapped
        assert float(s.n_degraded_writes) > 0
        m = engine.summarize(s, cfg)
        assert m["degraded"] == 1.0
        assert m["degraded_writes"] == float(s.n_degraded_writes)
        assert m["spares_remaining"] == 0.0 and m["spares_total"] == 2.0
        # reads still serve every mapped page: bijection intact
        l2p = np.asarray(s.l2p)
        assert (l2p >= 0).all()

    def test_unbounded_pool_never_degrades(self):
        cfg = _pressure_cfg()  # spare_blocks defaults to -1
        tr = _mixed(cfg, n=16_384, read_frac=0.1, write_theta=2.0)
        s, _ = engine.run(cfg, tr)
        assert int(s.spare_total) == st.SPARE_UNLIMITED
        assert float(s.n_degraded_writes) == 0.0
        m = engine.summarize(s, cfg)
        # sentinel pool reports as unbounded, not as a huge number
        assert m["spares_total"] == -1.0 and m["spares_remaining"] == -1.0
        assert m["degraded"] == 0.0

    def test_capacity_summary_reflects_spare_coverage(self, drained):
        cfg, s = drained
        m = engine.summarize(s, cfg)
        # retirements beyond the pool size are real capacity loss; the
        # covered part is credited back into effective capacity
        assert m["spare_covered_gib"] >= 0.0
        assert m["effective_capacity_gib"] == pytest.approx(
            m["capacity_gib"] + m["spare_covered_gib"])
        assert m["bad_blocks"] == float(s.bad_count) > 2

    R = 3  # static batch width -> one compile reused across examples

    @settings(max_examples=8, deadline=None)
    @given(
        spares=st_h.lists(st_h.integers(0, 5), min_size=R, max_size=R),
        slope=st_h.lists(st_h.floats(0.0, 16.0), min_size=R, max_size=R),
        seed=st_h.integers(0, 2**16),
    )
    def test_exhaustion_never_corrupts_mapping(self, spares, slope, seed):
        """Property: any spare-pool size crossed with any wear slope keeps
        every per-run state consistent — mapping bijection, exact free
        counts, spare accounting, and degraded writes only after the pool
        actually ran dry."""
        cfg = geometry.tiny_config(policy=geometry.RARO, n_logical=2_944,
                                   gc_free_threshold=18, gc_victims_per_pass=4)
        tr = _mixed(cfg, n=2_048, read_frac=0.3, write_theta=2.0)
        lpns = np.broadcast_to(np.asarray(tr["lpn"], np.int32),
                               (self.R, *tr["lpn"].shape))
        ops = np.broadcast_to(np.asarray(tr["op"], np.int32),
                              (self.R, *tr["op"].shape))
        knobs = policies.RunKnobs(
            r1=np.full(self.R, cfg.r1, np.int32),
            r2_override=np.full(self.R, -1, np.int32),
            initial_pe=np.full(self.R, 900, np.int32),
            prog_fail_rate=np.full(self.R, 0.02, np.float32),
            erase_fail_rate=np.full(self.R, 0.2, np.float32),
            max_read_retries=np.full(self.R, 4, np.int32),
            fault_seed=np.asarray([seed + i for i in range(self.R)], np.int32),
            read_fail_rate=np.full(self.R, 0.01, np.float32),
            fault_wear_slope=np.asarray(slope, np.float32),
            parity_rebuild=np.ones(self.R, np.int32),
            spare_blocks=np.asarray(spares, np.int32),
        )
        states = jax.device_get(
            sweep._sweep_jit(cfg, lpns, ops, True, knobs, None))
        for i in range(self.R):
            s = sweep._take_run(states, i)
            st.check_invariants(s, cfg)
            assert int(s.spare_total) == spares[i]
            if float(s.n_degraded_writes) > 0:
                assert int(s.spare_count) == 0
            assert float(s.n_data_loss) <= float(s.n_rebuilds)
            assert float(s.n_rebuilds) <= float(s.n_uncorrectable)


# -------------------------- youngest-first alloc ---------------------------


class TestYoungestAlloc:
    def _aged_state(self, cfg):
        s = st.init_state(cfg)
        free = np.asarray(s.block_state) == st.FREE
        assert free.sum() >= 4
        # age blocks in reverse id order: the lowest-id free block is the
        # most worn, so the two policies must disagree
        pe = (cfg.n_blocks - np.arange(cfg.n_blocks)).astype(np.int32) * 10
        return s._replace(block_pe=np.asarray(pe)), free

    def test_default_policy_is_lowest_id(self):
        cfg = TINY
        s, free = self._aged_state(cfg)
        got = int(ftl.alloc_free_block(s, cfg=cfg))
        assert got == int(np.flatnonzero(free)[0])

    def test_youngest_picks_minimum_wear(self):
        cfg = geometry.tiny_config(alloc_policy="youngest")
        s, free = self._aged_state(cfg)
        got = int(ftl.alloc_free_block(s, cfg=cfg))
        ids = np.flatnonzero(free)
        pe = np.asarray(s.block_pe)
        assert got == ids[np.argmin(pe[ids])]
        assert got != int(ids[0])  # genuinely diverges from lowest-id

    def test_youngest_respects_die_affinity(self):
        cfg = geometry.tiny_config(alloc_policy="youngest")
        s, free = self._aged_state(cfg)
        lun = 1
        got = int(ftl.alloc_free_block(s, prefer_lun=lun, cfg=cfg))
        ids = np.flatnonzero(free)
        on_die = ids[ids % cfg.n_dies == lun]
        pe = np.asarray(s.block_pe)
        assert got == on_die[np.argmin(pe[on_die])]

    def test_invalid_policy_rejected(self):
        with pytest.raises(ValueError, match="alloc_policy"):
            geometry.tiny_config(alloc_policy="oldest")

    def test_youngest_run_levels_wear(self):
        """End to end: under write pressure the wear-levelled allocator
        keeps the P/E spread no worse than lowest-id, with a coherent
        state throughout."""
        mk = lambda pol: geometry.tiny_config(  # noqa: E731
            policy=geometry.BASELINE, n_logical=2_944, gc_free_threshold=18,
            gc_victims_per_pass=4, alloc_policy=pol)
        tr = _mixed(mk("youngest"), n=16_384, read_frac=0.1, write_theta=2.0)
        s_y, _ = engine.run(mk("youngest"), tr)
        s_l, _ = engine.run(mk("lowest_id"), tr)
        st.check_invariants(s_y, mk("youngest"))
        assert float(s_y.n_writes) > 0
        my = engine.summarize(s_y, mk("youngest"))
        ml = engine.summarize(s_l, mk("lowest_id"))
        assert my["pe_variance"] <= ml["pe_variance"] * 1.5 + 1.0


# ------------------------- windowed WAF time series ------------------------


class TestWafWindow:
    @pytest.fixture(scope="class")
    def ts_run(self):
        cfg = geometry.tiny_config(
            policy=geometry.RARO, initial_pe=500, obs_level="full",
            obs_windows=32, obs_window_ms=5.0, n_logical=2_944,
            gc_free_threshold=18, gc_victims_per_pass=4)
        tr = _mixed(cfg, n=16 * cfg.chunk, read_frac=0.3, write_theta=2.0)
        s, _ = engine.run(cfg, tr)
        return cfg, jax.device_get(s)

    def test_reloc_series_recorded(self, ts_run):
        cfg, s = ts_run
        ts = obs.decode_timeseries(s, cfg)
        assert "reloc_pages" in ts and "waf_window" in ts
        # windowed relocations never exceed the run total (windows past the
        # ring capacity are dropped, not wrapped)
        assert 0.0 <= ts["reloc_pages"].sum() <= float(s.n_reloc_pages)

    def test_waf_window_bounded_below_by_one(self, ts_run):
        cfg, s = ts_run
        ts = obs.decode_timeseries(s, cfg)
        assert np.isfinite(ts["waf_window"]).all()
        assert (ts["waf_window"] >= 1.0).all()
        # pressure windows actually amplified: some window exceeds 1.0
        assert (ts["waf_window"] > 1.0).any()

    def test_chunk_metrics_split_user_and_reloc_pages(self, ts_run):
        cfg, s = ts_run
        tr = _mixed(cfg, n=16 * cfg.chunk, read_frac=0.3, write_theta=2.0)
        _, m = engine.run(cfg, tr)
        user = np.asarray(m.user_pages, np.float64)
        reloc = np.asarray(m.reloc_pages, np.float64)
        assert user.sum() == float(s.n_writes)
        assert reloc.sum() == float(s.n_reloc_pages)
        assert (user >= 0).all() and (reloc >= 0).all()
