"""Unit tests for the host-side workload generators (repro.ssdsim.workload):
packing invariants, distribution properties, determinism."""

import numpy as np

from repro.ssdsim import geometry, workload
from repro.ssdsim.engine import OP_READ, OP_WRITE

TINY = geometry.tiny_config()


class TestPack:
    def test_pads_to_chunk_multiple(self):
        n = TINY.chunk + 7  # forces one padded chunk
        lpn = np.arange(n, dtype=np.int32)
        op = np.full(n, OP_READ, np.int32)
        tr = workload._pack(TINY, lpn, op)
        n_chunks = -(-n // TINY.chunk)
        assert tr["lpn"].shape == (n_chunks, TINY.chunk)
        assert tr["op"].shape == (n_chunks, TINY.chunk)

    def test_padding_is_invalid_reads(self):
        n = TINY.chunk - 3
        tr = workload._pack(TINY, np.arange(n, dtype=np.int32),
                            np.full(n, OP_WRITE, np.int32))
        flat_lpn = tr["lpn"].reshape(-1)
        flat_op = tr["op"].reshape(-1)
        # padding lanes are lpn == -1 with a harmless read op
        assert (flat_lpn[n:] == -1).all()
        assert (flat_op[n:] == OP_READ).all()
        # payload is untouched
        np.testing.assert_array_equal(flat_lpn[:n], np.arange(n))
        assert (flat_op[:n] == OP_WRITE).all()

    def test_exact_multiple_has_no_padding(self):
        n = 2 * TINY.chunk
        tr = workload._pack(TINY, np.zeros(n, np.int32), np.full(n, OP_READ, np.int32))
        assert tr["lpn"].shape == (2, TINY.chunk)
        assert (tr["lpn"] >= 0).all()

    def test_dtypes(self):
        tr = workload._pack(TINY, np.arange(10, dtype=np.int64),
                            np.full(10, OP_READ, np.int64))
        assert tr["lpn"].dtype == np.int32 and tr["op"].dtype == np.int32


class TestZipfProbs:
    def test_normalized(self):
        for theta in (0.0, 0.6, 1.2, 2.0):
            p = workload.zipf_probs(1000, theta)
            assert abs(p.sum() - 1.0) < 1e-12
            assert (p >= 0).all()

    def test_monotone_decreasing_in_rank(self):
        p = workload.zipf_probs(100, 1.2)
        assert (np.diff(p) <= 0).all()

    def test_theta_zero_is_uniform(self):
        p = workload.zipf_probs(50, 0.0)
        np.testing.assert_allclose(p, 1.0 / 50)

    def test_higher_theta_more_skewed(self):
        lo = workload.zipf_probs(100, 0.8)
        hi = workload.zipf_probs(100, 1.5)
        assert hi[0] > lo[0]


class TestTraces:
    def test_mixed_trace_read_fraction(self):
        n = 20_000
        tr = workload.mixed_trace(TINY, n, 1.2, read_frac=0.7, seed=0)
        reads = (tr["op"].reshape(-1)[:n] == OP_READ).sum()
        assert abs(reads / n - 0.7) < 0.02  # binomial tolerance

    def test_mixed_trace_write_targets_uniform(self):
        """Regression (ISSUE 3): write LPNs must be uniform-random over the
        logical space (paper §V-A), not drawn from the Zipf-permuted read
        stream — reads stay heavily skewed, writes must not be."""
        n = 40_000
        tr = workload.mixed_trace(TINY, n, theta=1.2, read_frac=0.5, seed=0)
        lpn = tr["lpn"].reshape(-1)[:n]
        op = tr["op"].reshape(-1)[:n]
        r_lpn = lpn[op == OP_READ]
        w_lpn = lpn[op == OP_WRITE]
        L = TINY.n_logical
        r_counts = np.bincount(r_lpn, minlength=L)
        w_counts = np.bincount(w_lpn, minlength=L)
        # reads: Zipf(1.2) concentrates a large share on the few hottest
        # pages; writes: the most-written page of a uniform draw stays tiny
        assert np.sort(r_counts)[-10:].sum() > 0.2 * len(r_lpn)
        assert w_counts.max() < 0.005 * len(w_lpn)
        # chi-square-style uniformity: variance of uniform multinomial
        # counts stays near its expectation (p ~ n/L per page)
        expect = len(w_lpn) / L
        assert w_counts.var() < 3.0 * expect

    def test_mixed_trace_write_theta_skews_writes(self):
        """``write_theta`` opts into Zipf-skewed overwrites (the gc_pressure
        benchmark workload): hot pages are rewritten repeatedly, while the
        default stays uniform; the write permutation is independent of the
        read permutation."""
        n = 40_000
        tr = workload.mixed_trace(TINY, n, theta=1.2, read_frac=0.5, seed=0,
                                  write_theta=2.0)
        lpn = tr["lpn"].reshape(-1)[:n]
        op = tr["op"].reshape(-1)[:n]
        w_lpn = lpn[op == OP_WRITE]
        w_counts = np.bincount(w_lpn, minlength=TINY.n_logical)
        # Zipf(2.0): the ten hottest write targets dominate the stream
        assert np.sort(w_counts)[-10:].sum() > 0.5 * len(w_lpn)
        # determinism
        tr2 = workload.mixed_trace(TINY, n, theta=1.2, read_frac=0.5, seed=0,
                                   write_theta=2.0)
        np.testing.assert_array_equal(tr["lpn"], tr2["lpn"])

    def test_lpns_in_range(self):
        for tr in (
            workload.zipf_read_trace(TINY, 5_000, 1.2, seed=3),
            workload.uniform_read_trace(TINY, 5_000, seed=3),
            workload.seq_read_trace(TINY, 5_000, start=17),
            workload.mixed_trace(TINY, 5_000, 1.0, seed=3),
        ):
            lpn = tr["lpn"].reshape(-1)
            assert lpn.max() < TINY.n_logical
            assert lpn.min() >= -1

    def test_deterministic_under_fixed_seed(self):
        a = workload.zipf_read_trace(TINY, 4_000, 1.2, seed=9)
        b = workload.zipf_read_trace(TINY, 4_000, 1.2, seed=9)
        np.testing.assert_array_equal(a["lpn"], b["lpn"])
        m1 = workload.mixed_trace(TINY, 4_000, 1.2, seed=9)
        m2 = workload.mixed_trace(TINY, 4_000, 1.2, seed=9)
        np.testing.assert_array_equal(m1["lpn"], m2["lpn"])
        np.testing.assert_array_equal(m1["op"], m2["op"])

    def test_different_seeds_differ(self):
        a = workload.zipf_read_trace(TINY, 4_000, 1.2, seed=1)
        b = workload.zipf_read_trace(TINY, 4_000, 1.2, seed=2)
        assert (a["lpn"] != b["lpn"]).any()

    def test_seq_trace_wraps(self):
        tr = workload.seq_read_trace(TINY, TINY.n_logical + 10, start=0)
        lpn = tr["lpn"].reshape(-1)[: TINY.n_logical + 10]
        np.testing.assert_array_equal(lpn[:5], [0, 1, 2, 3, 4])
        np.testing.assert_array_equal(lpn[TINY.n_logical:], np.arange(10))
