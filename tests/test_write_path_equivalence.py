"""Equivalence tests: the vectorized write path vs. the retained scan
reference (DESIGN.md §2A).

``engine.write_path_batched`` must produce state equivalent to
``engine.write_path_reference`` on arbitrary mixed traces — including
duplicate LPNs within a chunk, open-block rollover mid-chunk, and
allocation failure when the free pool exhausts. Integer/mapping state must
match exactly; float accumulators (busy time) may differ by summation
order only.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hyp_fallback import given, settings
from hyp_fallback import st as st_h

from repro.ssdsim import engine, geometry, state as st, workload

TINY = geometry.tiny_config()


def assert_state_equivalent(s_ref: st.SSDState, s_bat: st.SSDState, tag=""):
    for name, a, b in zip(s_ref._fields, s_ref, s_bat):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype.kind == "f":
            np.testing.assert_allclose(
                a, b, rtol=1e-5, atol=1e-5, err_msg=f"{tag}: float field {name}"
            )
        else:
            bad = np.nonzero(np.atleast_1d(a != b))[0]
            assert (a == b).all(), (
                f"{tag}: field {name} differs at {bad[:8]}: "
                f"ref={np.atleast_1d(a)[bad][:8]} bat={np.atleast_1d(b)[bad][:8]}"
            )


def _run_both(s0, lpns, is_write, cfg):
    s_ref = engine.write_path_reference(s0, lpns, is_write, cfg)
    s_bat = engine.write_path_batched(s0, lpns, is_write, cfg)
    st.check_invariants(s_bat, cfg, "batched write path")
    return s_ref, s_bat


@settings(max_examples=12, deadline=None)
@given(
    seed=st_h.integers(0, 2**16),
    theta=st_h.floats(0.6, 1.5),
    read_frac=st_h.floats(0.0, 0.9),
)
def test_property_write_paths_equivalent(seed, theta, read_frac):
    """Random mixed traces, chunk by chunk, comparing full state each step.

    Zipf LPNs give duplicate writes within a chunk; chunk (128) > QLC pages
    per block (64) makes open-block rollover routine.
    """
    cfg = TINY
    tr = workload.mixed_trace(cfg, 3 * cfg.chunk, theta, read_frac=read_frac, seed=seed)
    s_ref = s_bat = st.init_state(cfg)
    for i in range(tr["lpn"].shape[0]):
        lp = jnp.asarray(tr["lpn"][i])
        w = jnp.asarray(tr["op"][i]) == engine.OP_WRITE
        s_ref = engine.write_path_reference(s_ref, lp, w, cfg)
        s_bat = engine.write_path_batched(s_bat, lp, w, cfg)
        assert_state_equivalent(s_ref, s_bat, tag=f"chunk {i}")
        st.check_invariants(s_bat, cfg, f"chunk {i}")


def test_single_lun_rollover_equivalent():
    """All writes on one LUN: the chunk spans two fresh blocks (128 > 64)."""
    cfg = TINY
    s0 = st.init_state(cfg)
    lp = jnp.asarray((np.arange(cfg.chunk) * cfg.n_luns) % cfg.n_logical, jnp.int32)
    w = jnp.ones(cfg.chunk, bool)
    s_ref, s_bat = _run_both(s0, lp, w, cfg)
    assert_state_equivalent(s_ref, s_bat, "rollover")
    assert float(s_bat.n_writes) == cfg.chunk


def test_duplicate_lpns_equivalent():
    """A handful of LPNs overwritten many times in one chunk: only the last
    write of each maps; earlier ones consume slots and are invalidated."""
    cfg = TINY
    s0 = st.init_state(cfg)
    lp = jnp.asarray(np.tile([0, 1, 4, 5], cfg.chunk // 4), jnp.int32)
    w = jnp.ones(cfg.chunk, bool)
    s_ref, s_bat = _run_both(s0, lp, w, cfg)
    assert_state_equivalent(s_ref, s_bat, "dups")
    l2p = np.asarray(s_bat.l2p)
    p2l = np.asarray(s_bat.p2l)
    for lpn in (0, 1, 4, 5):
        assert p2l[l2p[lpn]] == lpn


def test_allocation_failure_mid_chunk_equivalent():
    """Exactly one free block left: one rollover succeeds, the next fails,
    and every later write on that LUN fails identically in both paths."""
    base = geometry.tiny_config()
    cfg = geometry.tiny_config(
        n_logical=base.n_blocks * base.slots_per_block - base.slots_per_block - 32
    )
    s0 = st.init_state(cfg)
    assert int(s0.free_count) == 1
    free_blk = int(np.nonzero(np.asarray(s0.block_state) == st.FREE)[0][0])
    lun = free_blk % cfg.n_luns
    # every write targets the free block's LUN so the single spare is consumed
    # mid-chunk and the remaining writes hit allocation failure
    lp = jnp.asarray(
        (lun + np.arange(cfg.chunk) * cfg.n_luns) % cfg.n_logical, jnp.int32
    )
    w = jnp.ones(cfg.chunk, bool)
    s_ref, s_bat = _run_both(s0, lp, w, cfg)
    assert_state_equivalent(s_ref, s_bat, "alloc-failure")
    assert 0 < float(s_bat.n_writes) < cfg.chunk  # partial progress, then fail
    assert int(s_bat.free_count) == 0
    assert int(s_bat.open_user[lun]) == -1


def test_device_full_no_writes_equivalent():
    """Zero free blocks and no open block: every write fails, state (other
    than the open_user reset) is untouched."""
    base = geometry.tiny_config()
    cfg = geometry.tiny_config(n_logical=base.n_blocks * base.slots_per_block - 32)
    s0 = st.init_state(cfg)
    assert int(s0.free_count) == 0
    lp = jnp.asarray(np.arange(cfg.chunk, dtype=np.int32) % cfg.n_logical)
    w = jnp.ones(cfg.chunk, bool)
    s_ref, s_bat = _run_both(s0, lp, w, cfg)
    assert_state_equivalent(s_ref, s_bat, "device-full")
    assert float(s_bat.n_writes) == 0.0
    np.testing.assert_array_equal(np.asarray(s_bat.l2p), np.asarray(s0.l2p))


def test_reads_never_touch_write_path_state():
    """A pure-read mask is a no-op for both implementations."""
    cfg = TINY
    s0 = st.init_state(cfg)
    lp = jnp.asarray(np.arange(cfg.chunk, dtype=np.int32))
    w = jnp.zeros(cfg.chunk, bool)
    s_ref, s_bat = _run_both(s0, lp, w, cfg)
    assert_state_equivalent(s_ref, s_bat, "no-writes")
    assert float(s_bat.n_writes) == 0.0
    assert float(s_bat.w_lat_hist.sum()) == 0.0
